"""Multi-device behaviour via subprocesses (the main process must keep its
single CPU device — XLA locks device count at first init).

Covers: sharded training on a (2,2) mesh, elastic shrink after a simulated
node failure (restore-with-reshard + deterministic data replay), and
production-mesh construction with 512 placeholder devices.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_py(code: str, devices: int, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


@pytest.mark.slow
def test_sharded_training_matches_single_device():
    """Loss trajectory on a (2,2) mesh == single-device trajectory."""
    code = """
    import jax, numpy as np
    from repro.configs import get_config, reduced_config
    from repro.models import build_model
    from repro.train.trainer import TrainerConfig, init_train_state, make_train_step
    from repro.data.pipeline import SyntheticLMData, shard_batch
    from repro.launch.mesh import make_mesh
    from repro.parallel.sharding import RULES_TRAIN, set_activation_sharder
    from repro.optim.adamw import OptState
    from repro.train.trainer import TrainState
    from jax.sharding import NamedSharding, PartitionSpec as P

    assert len(jax.devices()) == 4
    cfg = reduced_config(get_config("llama32_1b"))
    model = build_model(cfg)
    tcfg = TrainerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)
    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)

    def run(mesh_shape):
        mesh = make_mesh(mesh_shape, ("data", "model"))
        axes, shapes = model.logical_axes(), model.init_shapes()
        p_sh = {k: RULES_TRAIN.sharding_for(axes[k], shapes[k].shape, mesh)
                for k in shapes}
        state_sh = TrainState(params=p_sh,
                              opt=OptState(mu=dict(p_sh), nu=dict(p_sh),
                                           count=NamedSharding(mesh, P())),
                              step=NamedSharding(mesh, P()))
        state = jax.device_put(
            __import__("repro.train.trainer", fromlist=["init_train_state"])
            .init_train_state(model, jax.random.PRNGKey(0), tcfg), state_sh)
        step = jax.jit(make_train_step(model, tcfg),
                       in_shardings=(state_sh, None), out_shardings=(state_sh, None))
        losses = []
        for i in range(6):
            with set_activation_sharder(mesh, RULES_TRAIN), mesh:
                db = shard_batch(data.batch_at(i), mesh, RULES_TRAIN)
                state, m = step(state, db)
            losses.append(float(m["loss"]))
        return losses

    l_multi = run((2, 2))
    l_single = run((1, 1))
    np.testing.assert_allclose(l_multi, l_single, rtol=2e-2, atol=2e-3)
    print("MULTIDEV_OK", l_multi[-1])
    """
    r = run_py(code, devices=4)
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_elastic_shrink_and_recover():
    """Simulated node failure at step 7: shrink data axis 4 -> 2, restore the
    latest checkpoint onto the new mesh, and keep training."""
    code = """
    import jax, numpy as np, tempfile
    from repro.configs import get_config, reduced_config
    from repro.models import build_model
    from repro.train.trainer import TrainerConfig
    from repro.train.elastic import ElasticConfig, ElasticTrainer
    from repro.data.pipeline import SyntheticLMData

    assert len(jax.devices()) == 4
    cfg = reduced_config(get_config("llama32_1b"))
    model = build_model(cfg)
    tcfg = TrainerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20)
    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    with tempfile.TemporaryDirectory() as d:
        et = ElasticTrainer(model, tcfg,
                            ElasticConfig(data_shards=4, model_shards=1,
                                          checkpoint_every=5, checkpoint_dir=d),
                            data, failure_schedule={7: 2})
        state, history = et.run(12)
    assert len(et.events) == 2, et.events
    assert any("reconfigure to 2" in e for e in et.events)
    assert int(state.step) == 12
    losses = [h["loss"] for h in history]
    assert all(np.isfinite(losses))
    print("ELASTIC_OK", et.events, losses[-1])
    """
    r = run_py(code, devices=4)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_production_mesh_512():
    """make_production_mesh builds both the 16x16 and 2x16x16 meshes with 512
    placeholder devices, and a tiny step lowers+compiles on each."""
    code = """
    import jax, jax.numpy as jnp
    from repro.launch.mesh import make_production_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P

    single = make_production_mesh()
    multi = make_production_mesh(multi_pod=True)
    assert dict(single.shape) == {"data": 16, "model": 16}
    assert dict(multi.shape) == {"pod": 2, "data": 16, "model": 16}

    x = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    for mesh, spec in ((single, P("data", "model")),
                       (multi, P(("pod", "data"), "model"))):
        sh = NamedSharding(mesh, spec)
        f = jax.jit(lambda a: (a * 2).sum(), in_shardings=(sh,))
        compiled = f.lower(x).compile()
        assert compiled.cost_analysis() is not None
    print("MESH512_OK")
    """
    r = run_py(code, devices=512)
    assert "MESH512_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_ep_moe_matches_dense():
    """shard_map expert-parallel MoE (the §Perf dispatch fix) computes the
    same function as the dense reference, for both the expert-sharded (E
    divides model axis) and FFN-sharded (E doesn't divide) paths."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import MoEConfig, ModelConfig
    from repro.models import layers as L
    from repro.parallel.sharding import RULES_TRAIN, set_activation_sharder

    for E in (8, 6):
        cfg = ModelConfig(name="t", family="moe", num_layers=2, d_model=32,
                          num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                          moe=MoEConfig(num_experts=E, top_k=2, d_ff_expert=64))
        rng = np.random.default_rng(0)
        p = {"moe/router": jnp.asarray(rng.standard_normal((32, E)) * 0.1, jnp.float32),
             "moe/we_gate": jnp.asarray(rng.standard_normal((E, 32, 64)) * 0.1, jnp.float32),
             "moe/we_up": jnp.asarray(rng.standard_normal((E, 32, 64)) * 0.1, jnp.float32),
             "moe/we_down": jnp.asarray(rng.standard_normal((E, 64, 32)) * 0.1, jnp.float32)}
        x = jnp.asarray(rng.standard_normal((4, 16, 32)), jnp.float32)
        y_ref, aux_ref = L.moe_apply_dense(cfg, p, "moe", x)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with set_activation_sharder(mesh, RULES_TRAIN), mesh:
            y, aux = jax.jit(lambda p, x: L.moe_apply_dropless_ep(
                cfg, p, "moe", x, capacity_factor=4.0))(p, x)
            g = jax.jit(jax.grad(lambda p, x: L.moe_apply_dropless_ep(
                cfg, p, "moe", x, capacity_factor=4.0)[0].sum()))(p, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-4)
        assert all(np.all(np.isfinite(np.asarray(v))) for v in g.values())
    print("EPMOE_OK")
    """
    r = run_py(code, devices=8)
    assert "EPMOE_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_compressed_train_step_tracks_exact():
    """End-to-end: the int8-EF compressed cross-pod train step follows the
    exact train step's loss trajectory on a (pod=2, data=2, model=2) mesh."""
    code = """
    import jax, jax.numpy as jnp, dataclasses
    from repro.configs import get_config, reduced_config
    from repro.models import build_model
    from repro.train.trainer import (TrainerConfig, init_train_state,
                                     make_train_step, make_train_step_compressed,
                                     init_compression_errors)
    from repro.data.pipeline import SyntheticLMData

    cfg = dataclasses.replace(reduced_config(get_config("llama32_1b")),
                              dtype="float32")
    model = build_model(cfg)
    tcfg = TrainerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20,
                         compute_dtype="float32")
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    state_c = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    state_r = jax.tree.map(jnp.copy, state_c)
    err = init_compression_errors(model, mesh, 2)
    with mesh:
        step_c = jax.jit(make_train_step_compressed(model, tcfg, mesh, None, None))
        step_r = jax.jit(make_train_step(model, tcfg))
        for i in range(6):
            batch = data.batch_at(i)
            state_c, err, mc = step_c(state_c, err, batch)
            state_r, mr = step_r(state_r, batch)
    diff = abs(float(mc["loss"]) - float(mr["loss"]))
    assert diff < 0.05, diff
    print("COMPTRAIN_OK", diff)
    """
    r = run_py(code, devices=8)
    assert "COMPTRAIN_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_compressed_cross_pod_psum():
    """int8 error-feedback gradient all-reduce over a 2-pod axis inside
    shard_map matches the exact mean within quantization tolerance."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.optim.grad_compress import (compressed_cross_pod_mean,
                                           init_compression_state)

    mesh = jax.make_mesh((2,), ("pod",))
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((2, 64)),
                          jnp.float32)}
    state = init_compression_state({"w": g["w"][0]})

    def f(gl, err):
        out, new_state = compressed_cross_pod_mean(
            {"w": gl["w"][0]}, state._replace(error={"w": err["w"][0]}), "pod")
        return out["w"], new_state.error["w"]

    sm = shard_map(f, mesh=mesh,
                   in_specs=({"w": P("pod")}, {"w": P("pod")}),
                   out_specs=(P(), P("pod")))
    err0 = {"w": jnp.zeros((2, 64), jnp.float32)}
    mean, new_err = sm(g, err0)
    want = np.asarray(g["w"]).mean(0)
    got = np.asarray(mean)
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 0.05, rel
    print("COMPRESS_OK", rel)
    """
    r = run_py(code, devices=2)
    assert "COMPRESS_OK" in r.stdout, r.stdout + r.stderr
