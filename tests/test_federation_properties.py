"""Property-based federation-tier invariants (hypothesis).

The digest contracts from the design:

1. **Fresh-digest equivalence** — with ``digest_interval=1`` and a digest
   wide enough to carry every live entry, the remote rung is hit-for-hit
   equivalent to brute-force probing every remote cluster's full shards.
2. **Staleness only under-reports** — with an arbitrary (stale) refresh
   interval, every payload served from the remote tier is a genuine
   above-threshold entry (never a phantom from a dead digest row), and the
   set of remote hits is a subset of what brute force would have served.
3. **Quantization only under-reports** — int8 digest probing serves a
   hit-for-hit subset of fp32 digest probing on identical state (the
   full-precision confirm gates both; rounding can only demote a
   near-threshold candidate to a recoverable miss).
4. **Delta refresh is exact** — after any interleaving of updates, the
   region replica reconstructed from push-on-delta messages is
   bit-identical to a full refresh of the current digest.

Seeded deterministic versions of (1), (3), (4) run in
``test_federation.py`` / ``test_digest.py`` so the invariants are always
exercised; this module widens the input space when ``hypothesis`` is
available."""
import numpy as np
import pytest

import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cluster import ClusterConfig
from repro.core.digest import (DigestConfig, DigestPublisher,
                               RegionDigestBoard)
from repro.core.federation import (TIER_MISS, TIER_REMOTE, FederatedEdgeTier,
                                   FederationConfig)

TAU = 0.8


def _mk(num_clusters, num_nodes, cap, d, p, digest_size, digest_interval,
        admission):
    return FederatedEdgeTier(FederationConfig(
        num_clusters=num_clusters, digest_size=digest_size,
        digest_interval=digest_interval,
        cluster=ClusterConfig(num_nodes=num_nodes, node_capacity=cap,
                              key_dim=d, payload_dim=p, threshold=TAU,
                              admission=admission)))


def _pool(seed, n, d):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _brute_force_remote(fed, k_home, q):
    """Would brute-force probing every OTHER cluster's full shards serve
    ``q``?  Uses the live states (called before the lookup mutates them)."""
    best = -np.inf
    for c, cl in enumerate(fed.clusters):
        if c == k_home:
            continue
        for s in cl.states:
            valid = np.asarray(s.valid)
            if valid.any():
                best = max(best, float(
                    (np.asarray(s.keys)[valid] @ q).max()))
    return best >= TAU


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_fresh_full_digest_equivalent_to_brute_force(data):
    """Contract (1): fresh, full-width digests serve exactly the requests
    brute force would — and the payloads are the probed entries' values."""
    K = data.draw(st.integers(2, 3), label="clusters")
    N = data.draw(st.integers(1, 2), label="nodes")
    cap = data.draw(st.integers(2, 6), label="capacity")
    d = 24
    pool = _pool(data.draw(st.integers(0, 9), label="pool_seed"), 12, d)
    pay = np.arange(12, dtype=np.float32)[:, None].repeat(3, axis=1)
    fed = _mk(K, N, cap, d, 3, digest_size=N * cap, digest_interval=1,
              admission=data.draw(st.sampled_from(
                  ["always", "never", "second_hit", "freq_weighted"]),
                  label="admission"))

    for _ in range(data.draw(st.integers(2, 5), label="rounds")):
        qids = np.array(data.draw(st.lists(
            st.integers(0, 11), min_size=K * N, max_size=K * N),
            label="qids")).reshape(K, N, 1)
        queries = pool[qids]
        want_remote = {}
        for k in range(K):
            for n in range(N):
                want_remote[(k, n)] = _brute_force_remote(
                    fed, k, queries[k, n, 0])
        res = fed.lookup_grouped(queries)
        for k in range(K):
            for n in range(N):
                t = int(res.tier[k, n, 0])
                if t == TIER_REMOTE:
                    assert want_remote[(k, n)]
                    np.testing.assert_allclose(
                        res.value[k, n, 0], pay[qids[k, n, 0]], rtol=1e-5)
                elif t == TIER_MISS:
                    # brute force would also have missed remotely
                    assert not want_remote[(k, n)]
                    fed.insert(k, n, jnp.asarray(queries[k, n]),
                               jnp.asarray(pay[qids[k, n]]))
    assert fed.digest_false_hits == 0


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_stale_digests_only_under_report(data):
    """Contract (2): with stale digests every remote-served payload is the
    requested scene's genuine value, remote hits are a subset of brute
    force, and false digest hits land in the counter, not in results."""
    K = data.draw(st.integers(2, 3), label="clusters")
    cap = data.draw(st.integers(1, 3), label="capacity")
    interval = data.draw(st.integers(2, 7), label="digest_interval")
    d = 24
    pool = _pool(data.draw(st.integers(0, 9), label="pool_seed"), 10, d)
    pay = np.arange(10, dtype=np.float32)[:, None].repeat(3, axis=1)
    fed = _mk(K, 1, cap, d, 3, digest_size=cap, digest_interval=interval,
              admission="never")

    n_remote = 0
    for _ in range(data.draw(st.integers(3, 8), label="rounds")):
        qids = np.array(data.draw(st.lists(
            st.integers(0, 9), min_size=K, max_size=K),
            label="qids")).reshape(K, 1, 1)
        queries = pool[qids]
        want_remote = {k: _brute_force_remote(fed, k, queries[k, 0, 0])
                       for k in range(K)}
        res = fed.lookup_grouped(queries)
        for k in range(K):
            t = int(res.tier[k, 0, 0])
            if t == TIER_REMOTE:
                n_remote += 1
                assert want_remote[k]            # subset of brute force
                np.testing.assert_allclose(
                    res.value[k, 0, 0], pay[qids[k, 0, 0]], rtol=1e-5)
            elif t == TIER_MISS:
                # a phantom digest row must surface as a counted false hit
                # (or a plain under-report) — never as a served payload
                np.testing.assert_array_equal(res.value[k, 0, 0],
                                              np.zeros(3))
                fed.insert(k, 0, jnp.asarray(queries[k, 0]),
                           jnp.asarray(pay[qids[k, 0]]))
    # eviction churn at capacity<=3 makes stale rows routine; the counter
    # must absorb them silently (no exception, no phantom serve)
    assert fed.digest_false_hits >= 0
    assert fed.stats()["tier_counts"]["remote"] == n_remote


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_int8_digest_probing_subset_of_fp32(data):
    """Contract (3): on identical shard contents with fresh full-width
    digests, every request the int8-digest tier serves remotely is served
    remotely by the fp32 tier too (same payload); int8 demotions are plain
    misses, never wrong payloads."""
    K = data.draw(st.integers(2, 3), label="clusters")
    N = data.draw(st.integers(1, 2), label="nodes")
    cap = data.draw(st.integers(2, 6), label="capacity")
    d = 24
    pool = _pool(data.draw(st.integers(0, 9), label="pool_seed"), 12, d)
    pay = np.arange(12, dtype=np.float32)[:, None].repeat(3, axis=1)
    feds = {q: _mk_quant(K, N, cap, d, 3, q) for q in ("fp32", "int8")}
    for k in range(K):
        for n in range(N):
            ids = np.array(data.draw(st.lists(
                st.integers(0, 11), min_size=1, max_size=cap),
                label=f"fill_{k}_{n}"))
            for fed in feds.values():
                fed.insert(k, n, jnp.asarray(pool[ids]),
                           jnp.asarray(pay[ids]))
    for _ in range(data.draw(st.integers(1, 3), label="rounds")):
        qids = np.array(data.draw(st.lists(
            st.integers(0, 11), min_size=K * N, max_size=K * N),
            label="qids")).reshape(K, N, 1)
        queries = pool[qids]
        res = {q: fed.lookup_grouped(queries) for q, fed in feds.items()}
        remote8 = res["int8"].tier == TIER_REMOTE
        remote32 = res["fp32"].tier == TIER_REMOTE
        assert (remote32 | ~remote8).all()
        if remote8.any():
            np.testing.assert_allclose(res["int8"].value[remote8],
                                       pay[qids[remote8]], rtol=1e-5)
        demoted = remote32 & ~remote8
        if demoted.any():
            assert (res["int8"].tier[demoted] == TIER_MISS).all()
            assert (res["int8"].value[demoted] == 0).all()


def _mk_quant(K, N, cap, d, p, quant):
    return FederatedEdgeTier(FederationConfig(
        num_clusters=K, digest_size=N * cap, digest_interval=1,
        digest_quant=quant,
        cluster=ClusterConfig(num_nodes=N, node_capacity=cap, key_dim=d,
                              payload_dim=p, threshold=TAU,
                              admission="never")))


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_delta_refresh_reconstructs_full_state(data):
    """Contract (4): after any interleaving of row mutations, validity
    flips, and no-op refreshes, the delta-reconstructed region replica is
    bit-identical to the full-refresh replica, and never ships more."""
    quant = data.draw(st.sampled_from(["fp32", "int8"]), label="quant")
    M = data.draw(st.integers(1, 8), label="M")
    D = data.draw(st.sampled_from([4, 16]), label="D")
    pub_f = DigestPublisher(DigestConfig(M, quant, "full"), D)
    board_f = RegionDigestBoard(DigestConfig(M, quant, "full"), 1, D)
    pub_d = DigestPublisher(DigestConfig(M, quant, "delta"), D)
    board_d = RegionDigestBoard(DigestConfig(M, quant, "delta"), 1, D)
    rng = np.random.default_rng(data.draw(st.integers(0, 99), label="seed"))
    keys = np.zeros((M, D), np.float32)
    valid = np.zeros((M,), bool)
    for step in range(data.draw(st.integers(1, 8), label="steps")):
        action = data.draw(st.sampled_from(["mutate", "flip", "noop"]),
                           label=f"a{step}")
        if action == "mutate":
            rows = rng.random(M) < 0.6
            keys[rows] = rng.standard_normal(
                (int(rows.sum()), D)).astype(np.float32)
            valid[rows] = True
        elif action == "flip":
            valid ^= rng.random(M) < 0.5
        board_f.apply(0, pub_f.publish(keys.copy(), valid.copy()))
        board_d.apply(0, pub_d.publish(keys.copy(), valid.copy()))
        np.testing.assert_array_equal(board_d.valid, board_f.valid)
        np.testing.assert_array_equal(board_d.probe_keys(),
                                      board_f.probe_keys())
    assert board_d.bytes_shipped <= board_f.bytes_shipped


# ---------------------------------------------------------------------------
# region_pin release: eviction and membership churn (the membership PR)
# ---------------------------------------------------------------------------


def _pin_rows(cl, key):
    """Valid rows of a 1-node cluster matching ``key``, and which of them
    the region_pin mask currently protects."""
    s = cl.states[0]
    valid = np.asarray(s.valid)
    match = valid & ((np.asarray(s.keys) @ key) >= TAU)
    pin = np.asarray(s.region_pin) & match
    return match, pin


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_region_pin_released_on_eviction_and_death(data):
    """Region-pin election invariants under an arbitrary interleaving of
    holder deaths, revives, and evictions of the shared entry:

      (a) pins only ever cover VALID rows,
      (b) a ground-truth-dead cluster holds no pins at all,
      (c) whenever any alive cluster still holds the shared entry hot,
          EXACTLY the lowest-id such holder pins it (deterministic
          re-election; an evicted or dead copy is never elected)."""
    import dataclasses as dc

    from repro.core.membership import ClusterMembership
    from repro.core.policies import EvictionPolicy

    K = data.draw(st.integers(2, 3), label="clusters")
    cap, d = 4, 24
    fed = FederatedEdgeTier(FederationConfig(
        num_clusters=K, digest_size=cap, digest_interval=1,
        cluster=ClusterConfig(
            num_nodes=1, node_capacity=cap, key_dim=d, payload_dim=3,
            threshold=TAU, policy=EvictionPolicy("lru", region_aware=True),
            admission="never")))
    mb = ClusterMembership(K, 1)
    fed.attach_membership(mb)
    pool = _pool(data.draw(st.integers(0, 9), label="pool_seed"), 12, d)
    shared = pool[0]

    def make_hot(k):
        fed.insert(k, 0, jnp.asarray(shared[None, :]),
                   jnp.ones((1, 3), jnp.float32))
        s = fed.clusters[k].states[0]
        fed.clusters[k].states[0] = dc.replace(
            s, peer_served=jnp.asarray(np.asarray(s.peer_served) + 2))

    for k in range(K):                               # every cluster holds it
        make_hot(k)
    fed.refresh_digests()

    def check():
        holders = []
        pinners = []
        for k, cl in enumerate(fed.clusters):
            match, pin = _pin_rows(cl, shared)
            s = cl.states[0]
            # (a) pins never cover invalid rows
            assert not (np.asarray(s.region_pin)
                        & ~np.asarray(s.valid)).any(), k
            if not mb.is_alive(k):
                # (b) dead clusters hold no pins
                assert not np.asarray(s.region_pin).any(), k
                continue
            hot = match & (np.asarray(s.peer_served) >= 1)
            if hot.any():
                holders.append(k)
            if pin.any():
                pinners.append(k)
        # (c) deterministic election: the lowest-id alive hot holder
        if holders:
            assert pinners == [holders[0]], (holders, pinners)
        else:
            assert pinners == []

    check()
    for step in range(data.draw(st.integers(1, 6), label="steps")):
        op = data.draw(st.sampled_from(["kill", "revive", "evict", "noop"]),
                       label=f"op{step}")
        if op == "kill":
            alive = [k for k in range(K) if mb.is_alive(k)]
            if len(alive) > 1:
                mb.kill_cluster(alive[0])            # takes the pin holder
        elif op == "revive":
            dead = [k for k in range(K) if not mb.cluster_alive[k]]
            if dead:
                mb.revive_cluster(dead[0])           # rejoins COLD
        elif op == "evict":
            # push the shared entry out of a random alive holder through
            # capacity pressure (unpinned copies go first; a pinned copy
            # is protected, so eviction only ever drops deferred replicas)
            alive = [k for k in range(K) if mb.is_alive(k)]
            k = alive[data.draw(st.integers(0, len(alive) - 1),
                                label=f"victim{step}")]
            fed.insert(k, 0, jnp.asarray(pool[1:1 + cap]),
                       jnp.ones((cap, 3), jnp.float32))
        fed.refresh_digests()
        check()


# ---------------------------------------------------------------------------
# Contract (5): IVF-PQ ANN probing only under-reports — for ANY codebook
# seed, fill pattern and tombstone interleaving, the confirmed ANN hits are
# a hit-for-hit subset of brute fp32 digest probing (the full-precision
# confirm gates both; the PQ approximation can only demote a candidate to a
# recoverable miss, never fabricate a payload).  Seeded deterministic
# versions run in test_digest.py; these widen the input space.
# ---------------------------------------------------------------------------


def _mk_ann(K, N, cap, d, p, *, interval, n_lists, n_sub, n_probe, seed):
    return FederatedEdgeTier(FederationConfig(
        num_clusters=K, digest_size=N * cap, digest_interval=interval,
        ann_mode="ivfpq", ann_min_rows=1, ann_lists=n_lists, ann_sub=n_sub,
        ann_probe=n_probe, ann_seed=seed, ann_admission=0.0,
        cluster=ClusterConfig(num_nodes=N, node_capacity=cap, key_dim=d,
                              payload_dim=p, threshold=TAU,
                              admission="never")))


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_ivfpq_probing_subset_of_fp32(data):
    """Contract (5) across drawn codebook seeds, fills, query rounds and
    tombstone interleavings."""
    K = data.draw(st.integers(2, 3), label="clusters")
    N = data.draw(st.integers(1, 2), label="nodes")
    cap = data.draw(st.integers(2, 6), label="capacity")
    d = 24
    interval = data.draw(st.sampled_from([1, 7]), label="digest_interval")
    n_lists = data.draw(st.sampled_from([2, 4]), label="ann_lists")
    n_sub = data.draw(st.sampled_from([2, 3, 4]), label="ann_sub")
    n_probe = min(n_lists, data.draw(st.integers(1, 4), label="ann_probe"))
    cb_seed = data.draw(st.integers(0, 2**31 - 1), label="codebook_seed")
    pool = _pool(data.draw(st.integers(0, 9), label="pool_seed"), 12, d)
    pay = np.arange(12, dtype=np.float32)[:, None].repeat(3, axis=1)
    feds = {"fp32": _mk(K, N, cap, d, 3, N * cap, interval, "never"),
            "ann": _mk_ann(K, N, cap, d, 3, interval=interval,
                           n_lists=n_lists, n_sub=n_sub, n_probe=n_probe,
                           seed=cb_seed)}
    for k in range(K):
        for n in range(N):
            ids = np.array(data.draw(st.lists(
                st.integers(0, 11), min_size=1, max_size=cap),
                label=f"fill_{k}_{n}"))
            for fed in feds.values():
                fed.insert(k, n, jnp.asarray(pool[ids]),
                           jnp.asarray(pay[ids]))
    for r in range(data.draw(st.integers(1, 3), label="rounds")):
        # tombstone interleaving: kill the same cluster's board rows on
        # BOTH tiers (with interval>1 the hole persists across rounds; with
        # interval=1 the next refresh revives it — both must stay subset)
        if data.draw(st.booleans(), label=f"tombstone_{r}"):
            dead = data.draw(st.integers(0, K - 1), label=f"dead_{r}")
            for fed in feds.values():
                fed.board.tombstone(dead)
        qids = np.array(data.draw(st.lists(
            st.integers(0, 11), min_size=K * N, max_size=K * N),
            label=f"qids_{r}")).reshape(K, N, 1)
        queries = pool[qids]
        res = {q: fed.lookup_grouped(queries) for q, fed in feds.items()}
        remote_a = res["ann"].tier == TIER_REMOTE
        remote32 = res["fp32"].tier == TIER_REMOTE
        assert (remote32 | ~remote_a).all()
        if remote_a.any():
            np.testing.assert_allclose(res["ann"].value[remote_a],
                                       pay[qids[remote_a]], rtol=1e-5)
        demoted = remote32 & ~remote_a
        if demoted.any():
            assert (res["ann"].tier[demoted] == TIER_MISS).all()
            assert (res["ann"].value[demoted] == 0).all()
    assert feds["ann"].max_ladder_dispatches <= 4


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_lists=st.sampled_from([2, 4, 8]),
       n_sub=st.sampled_from([2, 3, 4]), rows=st.integers(16, 64))
def test_codebook_training_deterministic(seed, n_lists, n_sub, rows):
    """Training is a pure function of (rows, knobs, seed): two runs agree
    bit-for-bit on centroids, codebook and the derived assignments."""
    from repro.core.digest import (assign_lists, encode_pq,
                                  train_pq_codebook)

    keys = _pool(seed % 1000, rows, 24)
    a = train_pq_codebook(keys, n_lists=n_lists, n_sub=n_sub, seed=seed,
                          iters=6)
    b = train_pq_codebook(keys, n_lists=n_lists, n_sub=n_sub, seed=seed,
                          iters=6)
    np.testing.assert_array_equal(a.centroids, b.centroids)
    np.testing.assert_array_equal(a.codebook, b.codebook)
    la = assign_lists(a, keys)
    np.testing.assert_array_equal(la, assign_lists(b, keys))
    resid = keys - a.centroids[la]
    np.testing.assert_array_equal(encode_pq(a, resid), encode_pq(b, resid))
