"""Unified tier-ladder protocol: canonical codes, the ≤4-dispatch bound
pinned through ``TierLadder`` counters, org-level CacheTier composition,
and the uniform per-tier stats shape across solo / cluster / federation
configs in both engines."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.cluster import ClusterConfig, CooperativeEdgeCluster
from repro.core.coic import CoICConfig, CoICEngine, recognition_cloud_fn
from repro.core.federation import FederatedEdgeTier, FederationConfig
from repro.core.tiers import (TIER_LOCAL, TIER_MISS, TIER_NAMES, TIER_PEER,
                              TIER_REMOTE, TierLadder, route_flat)
from repro.serving.engine import ServingConfig, ServingEngine


def _unit(rng, n, d):
    x = rng.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


LADDER_KEYS = {"tier_counts", "rung_dispatches", "probe_dispatches",
               "last_ladder_dispatches", "max_ladder_dispatches"}


def test_canonical_tier_codes_shared_across_layers():
    from repro.core import cluster as cl, federation as fed
    assert (TIER_LOCAL, TIER_PEER, TIER_REMOTE, TIER_MISS) == (0, 1, 2, 3)
    assert TIER_NAMES == ("local", "peer", "remote", "miss")
    assert (cl.TIER_LOCAL, cl.TIER_PEER, cl.TIER_MISS) == (0, 1, 3)
    assert (fed.TIER_LOCAL, fed.TIER_PEER, fed.TIER_REMOTE,
            fed.TIER_MISS) == (0, 1, 2, 3)


def test_ladder_bound_pinned_through_tierladder():
    """Regression for the ≤4 federation bound, now read off the shared
    TierLadder rather than bespoke per-layer counters: every rung is one
    batched dispatch (remote: probe + confirm) whatever K is."""
    rng = np.random.default_rng(0)
    d, p = 32, 4
    pool = _unit(rng, 16, d)
    for K in (2, 4):
        fed = FederatedEdgeTier(FederationConfig(
            num_clusters=K, digest_interval=1,
            cluster=ClusterConfig(num_nodes=2, node_capacity=8, key_dim=d,
                                  payload_dim=p, threshold=0.9)))
        for k in range(K):
            fed.insert(k, 0, jnp.asarray(pool[k:k + 4]),
                       jnp.zeros((4, p), jnp.float32))
        B = 4
        queries = pool[rng.integers(0, 16, size=(K, 2, B))]
        fed.lookup_grouped(queries)
        lad = fed.ladder.stats()
        assert lad["last_ladder_dispatches"] <= 4
        assert lad["max_ladder_dispatches"] <= 4
        assert set(lad["rung_dispatches"]) == {"local", "peer", "remote"}
        # every rung is at most one probe except remote's probe+confirm
        assert lad["rung_dispatches"]["local"] == 1
        assert lad["rung_dispatches"]["peer"] <= 1
        assert lad["rung_dispatches"]["remote"] <= 2
        assert set(lad["tier_counts"]) == set(TIER_NAMES)
        assert sum(lad["tier_counts"].values()) == K * 2 * B


def test_cluster_ladder_two_dispatch_bound():
    rng = np.random.default_rng(1)
    d = 32
    cl = CooperativeEdgeCluster(ClusterConfig(
        num_nodes=4, node_capacity=8, key_dim=d, payload_dim=2,
        threshold=0.9))
    cl.insert(0, jnp.asarray(_unit(rng, 4, d)),
              jnp.zeros((4, 2), jnp.float32))
    cl.lookup_grouped(jnp.asarray(_unit(rng, 4 * 3, d).reshape(4, 3, d)))
    assert cl.ladder.stats()["last_ladder_dispatches"] <= 2


def test_org_probe_is_a_cache_tier():
    """Org-level composition: an outer TierLadder can walk a cluster org
    directly (the CoICEngine shape, minus the cloud)."""
    rng = np.random.default_rng(2)
    d = 16
    cl = CooperativeEdgeCluster(ClusterConfig(
        num_nodes=2, node_capacity=8, key_dim=d, payload_dim=2,
        threshold=0.9))
    keys = _unit(rng, 4, d)
    cl.insert(0, jnp.asarray(keys), jnp.ones((4, 2), jnp.float32))
    outer = TierLadder([cl])
    queries = np.zeros((1, 2, 4, d), np.float32)
    queries[0, 1] = keys                              # node 1 asks: peer hits
    res = outer.probe(queries, np.ones((1, 2, 4), bool), None, 2, "float32")
    assert (res.tier[0, 1] == TIER_PEER).all()
    assert outer.stats()["rung_dispatches"]["edge"] <= 2


def test_route_flat_matches_grouped():
    """route_flat (pack -> probe -> unpack) returns exactly the grouped
    ladder's rows in submission order, mixed nodes included."""
    rng = np.random.default_rng(3)
    d = 32
    mk = ClusterConfig(num_nodes=3, node_capacity=16, key_dim=d,
                       payload_dim=2, threshold=0.9, admission="never")
    pool = _unit(rng, 8, d)
    cl_a, cl_b = CooperativeEdgeCluster(mk), CooperativeEdgeCluster(mk)
    for cl in (cl_a, cl_b):
        cl.insert(2, jnp.asarray(pool), jnp.ones((8, 2), jnp.float32))
    nodes = [0, 2, 1, 0, 2]
    desc = pool[[0, 1, 2, 3, 4]]
    flat = route_flat(cl_a, desc, nodes, [0] * 5)
    # oracle: group manually, call lookup_grouped on the twin
    queries = np.zeros((3, 2, d), np.float32)
    mask = np.zeros((3, 2), bool)
    slots = {0: 0, 1: 0, 2: 0}
    pos = {}
    for i, g in enumerate(nodes):
        queries[g, slots[g]] = desc[i]
        mask[g, slots[g]] = True
        pos[i] = (g, slots[g])
        slots[g] += 1
    res = cl_b.lookup_grouped(jnp.asarray(queries), mask)
    for i, (g, b) in pos.items():
        assert flat.tier[i] == res.tier[g, b]
        assert flat.hit[i] == res.hit[g, b]
        np.testing.assert_array_equal(flat.value[i], res.value[g, b])


# ---------------------------------------------------------------------------
# uniform stats across configs (the satellite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("conf", [
    dict(),                                          # solo cache
    dict(num_nodes=2),                               # cooperative cluster
    dict(num_nodes=2, num_clusters=2),               # federation
])
def test_coic_engine_ladder_stats_uniform(tiny_model, nprng, conf):
    model, params = tiny_model
    cloud = recognition_cloud_fn(model, params, num_classes=8)
    eng = CoICEngine(model, params,
                     CoICConfig(capacity=16, threshold=0.98, payload_dim=8,
                                descriptor="sketch", descriptor_dim=64,
                                **conf),
                     cloud_fn=cloud)
    toks = nprng.integers(0, model.cfg.vocab_size,
                          size=(3, 12)).astype(np.int32)
    eng.process_batch(toks)
    res = eng.process_batch(toks)                     # second pass: hits
    assert {r.source for r in res} == {"edge"}
    s = eng.stats()
    assert set(s["ladder"]) == LADDER_KEYS
    assert set(s["ladder"]["tier_counts"]) == set(TIER_NAMES)
    assert s["ladder"]["rung_dispatches"]["cloud"] == 1   # one cloud batch
    assert s["ladder"]["max_ladder_dispatches"] <= 4
    assert set(s["digest"]) >= {"mode", "bytes_shipped", "refreshes",
                                "false_hits"}
    if conf.get("num_clusters", 1) == 1:
        assert s["digest"]["mode"] == "off"
    assert s["deadline"]["observed"] == 0


@pytest.mark.parametrize("conf", [
    dict(),
    dict(num_nodes=2),
    dict(num_nodes=2, num_clusters=2),
])
def test_serving_engine_ladder_stats_uniform(tiny_model, nprng, conf):
    model, params = tiny_model
    eng = ServingEngine(model, params, ServingConfig(
        max_batch=4, max_len=64, max_new_tokens=4,
        coic=CoICConfig(capacity=16, threshold=0.98, descriptor="sketch",
                        descriptor_dim=64, **conf)))
    prompt = nprng.integers(0, model.cfg.vocab_size,
                            size=(12,)).astype(np.int32)
    eng.submit(prompt)
    eng.run_until_drained()
    eng.submit(prompt)
    eng.run_until_drained()
    assert eng.results[-1].source == "edge"
    s = eng.stats()
    assert set(s["ladder"]) == LADDER_KEYS
    assert set(s["ladder"]["tier_counts"]) == set(TIER_NAMES)
    assert s["ladder"]["max_ladder_dispatches"] <= 4
    assert s["digest"]["mode"] == ("full_fp32"
                                   if conf.get("num_clusters", 1) > 1
                                   else "off")
    assert s["semantic"]["hits"] >= 1
