"""Config integrity: every assigned arch loads with its published numbers."""
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, reduced_config, supports_cell
from repro.models.transformer import build_plan

EXPECTED = {
    "h2o_danube3_4b": dict(num_layers=24, d_model=3840, num_heads=32,
                           num_kv_heads=8, d_ff=10240, vocab_size=32000),
    "granite_20b": dict(num_layers=52, d_model=6144, num_heads=48,
                        num_kv_heads=1, d_ff=24576, vocab_size=49152),
    "llama32_1b": dict(num_layers=16, d_model=2048, num_heads=32,
                       num_kv_heads=8, d_ff=8192, vocab_size=128256),
    "qwen2_72b": dict(num_layers=80, d_model=8192, num_heads=64,
                      num_kv_heads=8, d_ff=29568, vocab_size=152064),
    "mamba2_2p7b": dict(num_layers=64, d_model=2560, vocab_size=50280),
    "whisper_small": dict(num_layers=12, d_model=768, num_heads=12,
                          d_ff=3072, vocab_size=51865),
    "deepseek_v2_lite_16b": dict(num_layers=27, d_model=2048, num_heads=16,
                                 vocab_size=102400),
    "granite_moe_3b_a800m": dict(num_layers=32, d_model=1536, num_heads=24,
                                 num_kv_heads=8, vocab_size=49155),
    "llava_next_34b": dict(num_layers=60, d_model=7168, num_heads=56,
                           num_kv_heads=8, d_ff=20480, vocab_size=64000),
    "jamba_v01_52b": dict(num_layers=32, d_model=4096, num_heads=32,
                          num_kv_heads=8, d_ff=14336, vocab_size=65536),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_config_numbers(arch):
    cfg = get_config(arch)
    for field, want in EXPECTED[arch].items():
        assert getattr(cfg, field) == want, (arch, field)


def test_qwen_has_qkv_bias():
    assert get_config("qwen2_72b").qkv_bias


def test_danube_has_sliding_window():
    assert get_config("h2o_danube3_4b").sliding_window > 0


def test_deepseek_mla_and_moe():
    cfg = get_config("deepseek_v2_lite_16b")
    assert cfg.mla is not None and cfg.mla.kv_lora_rank == 512
    assert cfg.moe.num_experts == 64 and cfg.moe.top_k == 6
    assert cfg.moe.num_shared_experts == 2
    assert cfg.moe.first_dense_layers == 1


def test_granite_moe_routing():
    cfg = get_config("granite_moe_3b_a800m")
    assert cfg.moe.num_experts == 40 and cfg.moe.top_k == 8


def test_jamba_interleave():
    cfg = get_config("jamba_v01_52b")
    kinds = [cfg.layer_kind(i) for i in range(cfg.num_layers)]
    assert kinds.count("attn") == 4            # 1:7 over 32 layers
    assert all(kinds[i] == "attn" for i in (4, 12, 20, 28))
    moes = [cfg.is_moe_layer(i) for i in range(cfg.num_layers)]
    assert sum(moes) == 16                     # every other layer


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_plan_covers_all_layers(arch):
    cfg = get_config(arch)
    if cfg.family == "encdec":
        pytest.skip("encdec stacks are explicit")
    plan = build_plan(cfg)
    total = sum(len(s.pattern) * s.repeats for s in plan)
    assert total == cfg.num_layers


def test_long_500k_skips_full_attention():
    cell = SHAPES["long_500k"]
    runnable = {a: supports_cell(get_config(a), cell)[0] for a in ARCH_IDS}
    assert runnable["mamba2_2p7b"] and runnable["jamba_v01_52b"]
    assert runnable["h2o_danube3_4b"]          # SWA => sub-quadratic
    for full_attn in ("granite_20b", "llama32_1b", "qwen2_72b", "whisper_small",
                      "deepseek_v2_lite_16b", "granite_moe_3b_a800m",
                      "llava_next_34b"):
        assert not runnable[full_attn], full_attn


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_same_family(arch):
    cfg = get_config(arch)
    red = reduced_config(cfg)
    assert red.family == cfg.family
    assert red.d_model <= 128 and red.vocab_size <= 512


def test_param_counts_match_billing():
    """Sanity: full-config parameter counts are near the advertised sizes."""
    expect = {"llama32_1b": (1.0e9, 1.7e9), "qwen2_72b": (70e9, 80e9),
              "mamba2_2p7b": (2.4e9, 3.0e9), "granite_20b": (18e9, 22e9)}
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        from repro.models import build_model
        from repro.utils.tree import tree_param_count

        n = tree_param_count(build_model(cfg).init_shapes())
        assert lo < n < hi, (arch, n)
