"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs the ref.py
pure-jnp oracle.  interpret mode executes the kernel body in Python on CPU,
validating BlockSpec indexing, online-softmax math and masking.

This file is the line of defense for every decode-path kernel: the ops.py
wrappers route ``impl="auto"`` to the jnp ref off-TPU, so CI never executes
a Pallas body through the serving path — only the explicit
``pallas_interpret`` cases here (and the engine-level ones in
test_kv_paged.py) do."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import (attention_kv_bytes_per_step,
                                           paged_attention,
                                           paged_attention_ref)
from repro.kernels.similarity import (similarity_lookup, similarity_topk_touch,
                                      similarity_topk_touch_ref)
from repro.serving.kv_cache import PagedKVCache


def _unit(rng, *shape):
    x = rng.normal(size=shape).astype(np.float32)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


class TestSimilarityKernel:
    @pytest.mark.parametrize("q,c,d", [(4, 32, 16), (128, 512, 64),
                                       (100, 1000, 48), (7, 513, 128),
                                       (1, 8, 256)])
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_matches_ref(self, q, c, d, dtype, nprng):
        qs = _unit(nprng, q, d)
        ks = _unit(nprng, c, d)
        ks[min(5, c - 1)] = qs[0]                     # guaranteed exact hit
        valid = nprng.random(c) > 0.3
        valid[min(5, c - 1)] = True
        qd, kd = jnp.asarray(qs, dtype), jnp.asarray(ks, dtype)
        i_ref, s_ref = similarity_lookup(qd, kd, jnp.asarray(valid), impl="ref")
        i_pal, s_pal = similarity_lookup(qd, kd, jnp.asarray(valid),
                                         impl="pallas_interpret",
                                         block_q=32, block_c=64)
        s_ref, s_pal = np.asarray(s_ref), np.asarray(s_pal)
        finite = np.isfinite(s_ref) & (s_ref > -1e29)
        np.testing.assert_allclose(s_ref[finite], s_pal[finite],
                                   rtol=2e-2, atol=2e-2)
        # ties may resolve differently; verify score at chosen index instead
        sc = qs @ ks.T
        sc[:, ~valid] = -np.inf
        chosen = sc[np.arange(q), np.asarray(i_pal)]
        np.testing.assert_allclose(chosen[finite], s_pal[finite],
                                   rtol=2e-2, atol=2e-2)

    def test_all_invalid_returns_neginf(self):
        q = jnp.ones((4, 16), jnp.float32) / 4.0
        k = jnp.ones((32, 16), jnp.float32) / 4.0
        valid = jnp.zeros((32,), bool)
        _, s = similarity_lookup(q, k, valid, impl="pallas_interpret",
                                 block_q=4, block_c=8)
        assert np.all(np.asarray(s) < -1e29)


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,k,d", [(1, 64, 4, 4, 16), (2, 128, 8, 2, 32),
                                           (1, 96, 4, 1, 64), (1, 64, 6, 3, 8)])
    @pytest.mark.parametrize("window", [0, 32])
    def test_matches_ref(self, b, s, h, k, d, window, nprng):
        q = nprng.normal(size=(b, s, h, d)).astype(np.float32)
        kk = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        v = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        o_ref = flash_attention(q, kk, v, causal=True, window=window, impl="ref")
        o_pal = flash_attention(q, kk, v, causal=True, window=window,
                                impl="pallas_interpret", block_q=32, block_kv=32)
        np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                                   np.asarray(o_pal, np.float32),
                                   rtol=2e-3, atol=2e-3)

    def test_bf16(self, nprng):
        b, s, h, k, d = 1, 64, 4, 2, 32
        q = jnp.asarray(nprng.normal(size=(b, s, h, d)), jnp.bfloat16)
        kk = jnp.asarray(nprng.normal(size=(b, s, k, d)), jnp.bfloat16)
        v = jnp.asarray(nprng.normal(size=(b, s, k, d)), jnp.bfloat16)
        o_ref = flash_attention(q, kk, v, impl="ref")
        o_pal = flash_attention(q, kk, v, impl="pallas_interpret",
                                block_q=32, block_kv=32)
        np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                                   np.asarray(o_pal, np.float32),
                                   rtol=3e-2, atol=3e-2)


class TestDecodeAttention:
    @pytest.mark.parametrize("b,s,h,k,d", [(2, 64, 4, 4, 16), (3, 100, 8, 2, 32),
                                           (1, 128, 4, 1, 64)])
    def test_matches_ref(self, b, s, h, k, d, nprng):
        q = nprng.normal(size=(b, h, d)).astype(np.float32)
        kk = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        v = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        kv_len = np.array([min(s, 7 + 13 * i) for i in range(b)], np.int32)
        o_ref = decode_attention(q, kk, v, kv_len, impl="ref")
        o_pal = decode_attention(q, kk, v, kv_len, impl="pallas_interpret",
                                 block_kv=32)
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                                   rtol=2e-3, atol=2e-3)

    def test_length_zero_safe(self, nprng):
        b, s, h, k, d = 1, 32, 2, 2, 8
        q = nprng.normal(size=(b, h, d)).astype(np.float32)
        kk = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        v = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        out = decode_attention(q, kk, v, np.zeros((b,), np.int32),
                               impl="pallas_interpret", block_kv=16)
        assert np.all(np.isfinite(np.asarray(out)))


def _paged_case(rng, *, B, page, n_pages, K, D, H=None, C=1, lengths=None,
                shared_pages=0, dtype=np.float32):
    """Build a pool + block tables the way PagedKVCache lays them out:
    rows map ceil(len / page) pages (first ``shared_pages`` of them shared
    across all rows — the prefix-index case), everything else INVALID."""
    H = H or K
    P = n_pages * B + 1                  # headroom: distinct pages per row
    if lengths is None:
        lengths = rng.integers(0, n_pages * page - C + 1, size=(B,))
    lengths = np.asarray(lengths, np.int32)
    kp = rng.normal(size=(P, page, K, D)).astype(dtype)
    vp = rng.normal(size=(P, page, K, D)).astype(dtype)
    bt = np.full((B, n_pages), PagedKVCache.INVALID, np.int32)
    nxt = shared_pages
    for b in range(B):
        used = -(-int(lengths[b] + C) // page)       # pages the row touches
        for j in range(min(used, n_pages)):
            if j < shared_pages:
                bt[b, j] = j
            else:
                bt[b, j] = nxt
                nxt += 1
    q = rng.normal(size=(B, C, H, D)).astype(dtype)
    return (jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(bt), jnp.asarray(lengths))


class TestPagedAttention:
    """Fused in-place paged attention vs the gather-path oracle (ref.py
    replicates ``_paged_view`` + the model's fp32-softmax GQA bit for bit,
    so ref-vs-interpret closeness here transfers to the serving path)."""

    @pytest.mark.parametrize("B,page,n_pages,K,H,D,C", [
        (3, 16, 4, 2, 4, 16, 1),       # GQA decode
        (2, 8, 6, 4, 4, 32, 1),        # MHA decode, ragged
        (2, 16, 4, 2, 8, 16, 8),       # chunked prefill, 4 q heads/group
        (1, 32, 2, 1, 2, 64, 16),      # single row, wide chunk
    ])
    def test_matches_gather_oracle(self, B, page, n_pages, K, H, D, C, nprng):
        q, kp, vp, bt, ln = _paged_case(nprng, B=B, page=page,
                                        n_pages=n_pages, K=K, H=H, D=D, C=C)
        o_ref = paged_attention(q, kp, vp, bt, ln, impl="ref")
        o_pal = paged_attention(q, kp, vp, bt, ln, impl="pallas_interpret")
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                                   rtol=2e-3, atol=2e-3)

    def test_partial_last_page_and_page_boundary(self, nprng):
        """Rows sitting mid-page, exactly on a page boundary, and at 0."""
        q, kp, vp, bt, ln = _paged_case(nprng, B=4, page=16, n_pages=4, K=2,
                                        H=4, D=16, lengths=[5, 16, 32, 0])
        o_ref = paged_attention(q, kp, vp, bt, ln, impl="ref")
        o_pal = paged_attention(q, kp, vp, bt, ln, impl="pallas_interpret")
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                                   rtol=2e-3, atol=2e-3)

    def test_idle_all_invalid_row_is_finite(self, nprng):
        """An idle decode slot rides the dispatch with an all-INVALID table
        row; the kernel must finalize it to exact zeros, not NaN."""
        q, kp, vp, bt, ln = _paged_case(nprng, B=3, page=16, n_pages=3, K=2,
                                        H=4, D=16, lengths=[20, 0, 7])
        bt = bt.at[1].set(PagedKVCache.INVALID)
        out = np.asarray(paged_attention(q, kp, vp, bt, ln,
                                         impl="pallas_interpret"))
        assert np.all(np.isfinite(out))
        np.testing.assert_array_equal(out[1], 0.0)

    def test_shared_prefix_pages(self, nprng):
        """Cross-user shared prefix pages: rows alias physical pages."""
        q, kp, vp, bt, ln = _paged_case(nprng, B=4, page=8, n_pages=6, K=2,
                                        H=4, D=16, shared_pages=2,
                                        lengths=[30, 22, 17, 40])
        assert np.array_equal(np.asarray(bt)[:, :2],
                              np.tile([[0, 1]], (4, 1)))
        o_ref = paged_attention(q, kp, vp, bt, ln, impl="ref")
        o_pal = paged_attention(q, kp, vp, bt, ln, impl="pallas_interpret")
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                                   rtol=2e-3, atol=2e-3)

    def test_bf16(self, nprng):
        q, kp, vp, bt, ln = _paged_case(nprng, B=2, page=16, n_pages=3, K=2,
                                        H=4, D=32, dtype=np.float32)
        q, kp, vp = (x.astype(jnp.bfloat16) for x in (q, kp, vp))
        o_ref = paged_attention(q, kp, vp, bt, ln, impl="ref")
        o_pal = paged_attention(q, kp, vp, bt, ln, impl="pallas_interpret")
        np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                                   np.asarray(o_pal, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_auto_routes_to_ref_off_tpu(self, nprng):
        """CI has no TPU: auto must be the jnp oracle, bit for bit."""
        q, kp, vp, bt, ln = _paged_case(nprng, B=2, page=16, n_pages=3, K=2,
                                        H=4, D=16)
        if jax.default_backend() == "tpu":
            pytest.skip("auto routes to the real kernel on TPU")
        np.testing.assert_array_equal(
            np.asarray(paged_attention(q, kp, vp, bt, ln, impl="auto")),
            np.asarray(paged_attention(q, kp, vp, bt, ln, impl="ref")))

    def test_seeded_property_sweep(self, nprng):
        """Seeded stand-in for the hypothesis sweep: random ragged lengths,
        INVALID rows, shared prefixes, chunk widths."""
        for trial in range(8):
            B = int(nprng.integers(1, 5))
            page = int(nprng.choice([8, 16]))
            n_pages = int(nprng.integers(2, 6))
            K = int(nprng.choice([1, 2, 4]))
            H = K * int(nprng.choice([1, 2, 4]))
            C = int(nprng.choice([1, 1, 4, 8]))
            q, kp, vp, bt, ln = _paged_case(
                nprng, B=B, page=page, n_pages=n_pages, K=K, H=H, D=16, C=C,
                shared_pages=int(nprng.integers(0, 2)))
            o_ref = paged_attention(q, kp, vp, bt, ln, impl="ref")
            o_pal = paged_attention(q, kp, vp, bt, ln,
                                    impl="pallas_interpret")
            np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                                       rtol=2e-3, atol=2e-3,
                                       err_msg=f"trial {trial}")

    def test_hypothesis_sweep(self, nprng):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=15, deadline=None)
        @given(B=st.integers(1, 4), page=st.sampled_from([8, 16]),
               n_pages=st.integers(2, 5), K=st.sampled_from([1, 2, 4]),
               G=st.sampled_from([1, 2, 4]), C=st.sampled_from([1, 4, 8]),
               seed=st.integers(0, 2**31 - 1))
        def check(B, page, n_pages, K, G, C, seed):
            rng = np.random.default_rng(seed)
            q, kp, vp, bt, ln = _paged_case(rng, B=B, page=page,
                                            n_pages=n_pages, K=K, H=K * G,
                                            D=16, C=C)
            o_ref = paged_attention(q, kp, vp, bt, ln, impl="ref")
            o_pal = paged_attention(q, kp, vp, bt, ln,
                                    impl="pallas_interpret")
            np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                                       rtol=2e-3, atol=2e-3)

        check()

    def test_byte_model(self):
        """The benchmark/docs byte model: in-place strictly below gather for
        any non-empty batch, and exactly the mapped-page traffic."""
        kv = np.array([100, 0, 17, 512])
        kw = dict(page_size=16, max_len=512, kv_heads=8, head_dim=32,
                  dtype_bytes=4)
        g = attention_kv_bytes_per_step(kv, impl="gather", **kw)
        p = attention_kv_bytes_per_step(kv, impl="paged", **kw)
        mapped = sum(-(-int(x) // 16) * 16 for x in kv)
        row = 2 * 8 * 32 * 4
        assert p == mapped * row
        assert g == (mapped + 2 * 4 * 512) * row
        assert p < g
        with pytest.raises(ValueError):
            attention_kv_bytes_per_step(kv, impl="nope", **kw)


class TestFusedTopkTouch:
    """Fused top-k + LRU-touch epilogue vs the unfused oracle."""

    def _case(self, rng, Q, C, D):
        q = _unit(rng, Q, D)
        ks = _unit(rng, C, D)
        # exact hits incl. two queries hitting the SAME slot (multiplicity)
        ks[3] = q[0]
        ks[11 % C] = q[1]
        if Q > 2:
            q[2] = q[0]
        valid = rng.random(C) > 0.3
        valid[[3, 11 % C]] = True
        lu = rng.integers(0, 50, C).astype(np.int32)
        fr = rng.integers(0, 50, C).astype(np.int32)
        return (jnp.asarray(q), jnp.asarray(ks), jnp.asarray(valid),
                jnp.asarray(lu), jnp.asarray(fr), jnp.asarray(np.int32(99)))

    @pytest.mark.parametrize("Q,C,D,k", [(8, 64, 16, 4), (5, 100, 32, 1),
                                         (16, 48, 16, 8)])
    def test_matches_unfused_oracle(self, Q, C, D, k, nprng):
        q, ks, valid, lu, fr, clock = self._case(nprng, Q, C, D)
        r_ref = similarity_topk_touch(q, ks, valid, k, lu, fr, clock,
                                      threshold=0.98, impl="ref")
        r_pal = similarity_topk_touch(q, ks, valid, k, lu, fr, clock,
                                      threshold=0.98, impl="pallas_interpret",
                                      block_c=16)
        for a, b, name in zip(r_ref, r_pal, ("idx", "score", "lu", "fr")):
            if name == "score":
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=2e-2, atol=2e-2)
            else:
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                              err_msg=name)

    def test_mask_rows_never_touch(self, nprng):
        q, ks, valid, lu, fr, clock = self._case(nprng, 8, 64, 16)
        mask = jnp.asarray(np.array([1, 0, 1, 1, 0, 1, 1, 1], bool))
        for impl in ("ref", "pallas_interpret"):
            _, _, lu2, fr2 = similarity_topk_touch(
                q, ks, valid, 2, lu, fr, clock, threshold=0.98, mask=mask,
                impl=impl, block_c=16)
            # query 1's exact hit is masked: its slot must be untouched
            assert int(fr2[11 % 64]) == int(fr[11 % 64]), impl
            # query 0 and its duplicate query 2 both touch slot 3
            assert int(fr2[3]) == int(fr[3]) + 2, impl

    def test_touch_semantics_match_apply_probe(self, nprng):
        """End-to-end: SemanticCache with fuse_touch=True transitions state
        exactly like the unfused lookup + apply_probe path."""
        import dataclasses

        from repro.core.semantic_cache import SemanticCache

        C, D, P, Q = 48, 16, 4, 8
        base = SemanticCache(capacity=C, key_dim=D, payload_dim=P,
                             threshold=0.9)
        st0 = base.init()
        ks = _unit(nprng, C, D)
        st0 = base.insert(st0, jnp.asarray(ks[:30]),
                          jnp.asarray(nprng.normal(size=(30, P)),
                                      jnp.float32))
        q = _unit(nprng, Q, D)
        q[0] = ks[3]
        q[1] = ks[3]
        q[2] = ks[7]
        mask = np.ones(Q, bool)
        mask[5] = False
        for impl in ("ref", "pallas_interpret"):
            fused = dataclasses.replace(base, fuse_touch=True,
                                        lookup_impl=impl)
            s1, r1 = base.lookup(st0, jnp.asarray(q), mask=jnp.asarray(mask))
            s2, r2 = fused.lookup(st0, jnp.asarray(q), mask=jnp.asarray(mask))
            np.testing.assert_array_equal(np.asarray(r1.hit),
                                          np.asarray(r2.hit), err_msg=impl)
            np.testing.assert_array_equal(np.asarray(r1.value),
                                          np.asarray(r2.value), err_msg=impl)
            for f in ("last_used", "freq", "clock", "hits", "misses",
                      "valid"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(s1, f)), np.asarray(getattr(s2, f)),
                    err_msg=f"{impl}:{f}")


class TestKernelVsModelAttention:
    def test_flash_equals_model_xla_path(self, nprng):
        """The kernel and the model's XLA attention implement the same op."""
        from repro.models import layers as L

        b, s, h, k, d = 2, 64, 4, 2, 16
        q = nprng.normal(size=(b, s, h, d)).astype(np.float32)
        kk = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        v = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        model_out = L.causal_attention(jnp.asarray(q), jnp.asarray(kk),
                                       jnp.asarray(v), pos, pos, causal=True)
        kern_out = flash_attention(q, kk, v, causal=True,
                                   impl="pallas_interpret",
                                   block_q=32, block_kv=32)
        np.testing.assert_allclose(np.asarray(model_out), np.asarray(kern_out),
                                   rtol=2e-3, atol=2e-3)


class TestIVFPQ:
    """Two-stage IVF-PQ digest probe: the Pallas body (interpret=True) must
    be BIT-exact against the jnp oracle — idx, score AND the probed-list
    selection.  The decode is a one-hot matmul (copies codebook entries
    exactly) and the merge replays ``lax.top_k`` tie order, so equality is
    ``assert_array_equal``, not allclose."""

    @staticmethod
    def _index(rng, L, cap, S, D, K):
        """Random packed index in the core/digest.py layout: some dead
        lists, ~30% tombstoned slots, owners spread over K clusters."""
        centroids = _unit(rng, L, D)
        cent_valid = rng.random(L) > 0.15
        cent_valid[: max(2, L // 4)] = True           # enough live lists
        codes = rng.integers(0, 256, size=(L, cap, S)).astype(np.uint8)
        slot_valid = rng.random((L, cap)) > 0.3
        slot_owner = rng.integers(0, K, size=(L, cap)).astype(np.int32)
        slot_valid &= cent_valid[:, None]             # dead list => dead slots
        codebook = (rng.standard_normal((S, 256, D // S)) * 0.05).astype(
            np.float32)
        return tuple(jnp.asarray(a) for a in
                     (centroids, cent_valid, codes, slot_valid, slot_owner,
                      codebook))

    @pytest.mark.parametrize("Q,L,cap,S,D,n_probe,k",
                             [(8, 8, 4, 2, 16, 3, 1), (16, 16, 8, 4, 32, 4, 4),
                              (8, 12, 6, 4, 16, 12, 2), (24, 9, 5, 8, 64, 1, 3)])
    def test_kernel_bit_exact_vs_oracle(self, Q, L, cap, S, D, n_probe, k,
                                        nprng):
        from repro.kernels.ivf_pq.kernel import ivf_pq_probe_kernel
        from repro.kernels.ivf_pq.ref import ivf_pq_probe_ref

        K = 3
        idxarrs = self._index(nprng, L, cap, S, D, K)
        q = jnp.asarray(_unit(nprng, Q, D))
        home = jnp.asarray(nprng.integers(0, K, size=Q).astype(np.int32))
        i_ref, s_ref, sel_ref = ivf_pq_probe_ref(q, home, *idxarrs, k=k,
                                                 n_probe=n_probe)
        i_pal, s_pal, sel_pal = ivf_pq_probe_kernel(q, home, *idxarrs, k=k,
                                                    n_probe=n_probe,
                                                    interpret=True)
        np.testing.assert_array_equal(np.asarray(sel_ref), np.asarray(sel_pal))
        np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_pal))
        np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_pal))

    def test_ops_pads_ragged_query_tile(self, nprng):
        """Public wrapper pads Q to a multiple of 8 (padded rows home=-1)
        and slices the outputs back — still bit-exact vs the ref impl."""
        from repro.kernels.ivf_pq import ivf_pq_probe

        idxarrs = self._index(nprng, 8, 4, 2, 16, 2)
        q = jnp.asarray(_unit(nprng, 5, 16))          # 5 % 8 != 0
        home = jnp.asarray(np.array([0, 1, 0, 1, 0], np.int32))
        i_ref, s_ref = ivf_pq_probe(q, home, *idxarrs, k=2, n_probe=3,
                                    impl="ref")
        i_pal, s_pal = ivf_pq_probe(q, home, *idxarrs, k=2, n_probe=3,
                                    impl="pallas_interpret")
        np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_pal))
        np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_pal))

    def test_auto_routes_to_ref_off_tpu(self, nprng):
        """CI has no TPU: auto must be the jnp oracle, bit for bit."""
        from repro.kernels.ivf_pq import ivf_pq_probe

        if jax.default_backend() == "tpu":
            pytest.skip("auto routes to the real kernel on TPU")
        idxarrs = self._index(nprng, 8, 4, 2, 16, 2)
        q = jnp.asarray(_unit(nprng, 8, 16))
        home = jnp.zeros(8, jnp.int32)
        for a, b in zip(ivf_pq_probe(q, home, *idxarrs, k=2, n_probe=4,
                                     impl="auto"),
                        ivf_pq_probe(q, home, *idxarrs, k=2, n_probe=4,
                                     impl="ref")):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_home_cluster_rows_never_match(self, nprng):
        """A probe must exclude its own cluster's advertised rows: with
        every slot owned by cluster 0, a home=0 query gets only NEG_INF
        sentinels while a home=1 query scores live slots."""
        from repro.kernels.ivf_pq import ivf_pq_probe

        centroids, cent_valid, codes, slot_valid, _, codebook = self._index(
            nprng, 8, 4, 2, 16, 2)
        owner0 = jnp.zeros((8, 4), jnp.int32)
        q = jnp.asarray(_unit(nprng, 8, 16))
        for impl in ("ref", "pallas_interpret"):
            _, s_home = ivf_pq_probe(q, jnp.zeros(8, jnp.int32), centroids,
                                     cent_valid, codes, slot_valid, owner0,
                                     codebook, k=1, n_probe=8, impl=impl)
            _, s_away = ivf_pq_probe(q, jnp.ones(8, jnp.int32), centroids,
                                     cent_valid, codes, slot_valid, owner0,
                                     codebook, k=1, n_probe=8, impl=impl)
            assert (np.asarray(s_home) < -1e29).all(), impl
            assert (np.asarray(s_away) > -1e29).any(), impl

    def test_hits_come_only_from_probed_lists(self, nprng):
        """With n_probe=1 every returned candidate's list (idx // cap) is
        the query's single selected list — stage 2 never leaks unprobed
        rows into the top-k."""
        from repro.kernels.ivf_pq.ref import ivf_pq_probe_ref

        L, cap = 12, 6
        idxarrs = self._index(nprng, L, cap, 4, 16, 3)
        q = jnp.asarray(_unit(nprng, 16, 16))
        home = jnp.asarray(nprng.integers(0, 3, size=16).astype(np.int32))
        idx, score, sel = ivf_pq_probe_ref(q, home, *idxarrs, k=3, n_probe=1)
        idx, score, sel = (np.asarray(a) for a in (idx, score, sel))
        real = score > -1e29
        assert (idx[real.all(axis=1)].min(initial=0) >= 0)
        lists = idx // cap
        assert (lists[real] == sel[:, 0][:, None].repeat(3, 1)[real]).all()

    def test_decode_is_exact_codebook_gather(self, nprng):
        """onehot(codes) @ codebook copies entries bitwise — the property
        the kernel/oracle bit-exactness rests on."""
        from repro.kernels.ivf_pq.ref import decode_pq_codes

        S, dsub = 4, 8
        cb = nprng.standard_normal((S, 256, dsub)).astype(np.float32)
        codes = nprng.integers(0, 256, size=(10, S))
        dec = np.asarray(decode_pq_codes(jnp.asarray(cb),
                                         jnp.asarray(codes.astype(np.int32))))
        want = np.concatenate([cb[s][codes[:, s]] for s in range(S)], axis=1)
        np.testing.assert_array_equal(dec, want)

    def test_hypothesis_sweep(self, nprng):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        from repro.kernels.ivf_pq.kernel import ivf_pq_probe_kernel
        from repro.kernels.ivf_pq.ref import ivf_pq_probe_ref

        @settings(max_examples=15, deadline=None)
        @given(Q=st.sampled_from([8, 16]), L=st.integers(4, 12),
               cap=st.integers(2, 8), S=st.sampled_from([2, 4]),
               n_probe=st.integers(1, 4), k=st.integers(1, 3),
               seed=st.integers(0, 2**31 - 1))
        def check(Q, L, cap, S, n_probe, k, seed):
            rng = np.random.default_rng(seed)
            n_probe = min(n_probe, L)
            idxarrs = self._index(rng, L, cap, S, 16, 3)
            q = jnp.asarray(_unit(rng, Q, 16))
            home = jnp.asarray(rng.integers(0, 3, size=Q).astype(np.int32))
            ref = ivf_pq_probe_ref(q, home, *idxarrs, k=k, n_probe=n_probe)
            pal = ivf_pq_probe_kernel(q, home, *idxarrs, k=k,
                                      n_probe=n_probe, interpret=True)
            for a, b, name in zip(ref, pal, ("idx", "score", "sel")):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                              err_msg=name)

        check()

    def test_byte_model(self):
        """ivf_pq scan traffic is n_sub+2 bytes/slot vs D+4 for the brute
        int8 board row; at region scale (1M rows) the model shows >=4x."""
        from repro.obs.profile import digest_probe_bytes, ivf_pq_probe_bytes

        rows, L, S, D, nq, K = 1_000_000, 1024, 8, 64, 64, 4
        ivf = ivf_pq_probe_bytes(nq, L, -(-rows // L), S, D)
        brute = digest_probe_bytes(nq // K, K, rows // K, D, "int8")
        assert ivf > 0
        assert brute / ivf >= 4.0, (brute, ivf)
