"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs the ref.py
pure-jnp oracle.  interpret mode executes the kernel body in Python on CPU,
validating BlockSpec indexing, online-softmax math and masking.

This file is the line of defense for every decode-path kernel: the ops.py
wrappers route ``impl="auto"`` to the jnp ref off-TPU, so CI never executes
a Pallas body through the serving path — only the explicit
``pallas_interpret`` cases here (and the engine-level ones in
test_kv_paged.py) do."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import (attention_kv_bytes_per_step,
                                           paged_attention,
                                           paged_attention_ref)
from repro.kernels.similarity import (similarity_lookup, similarity_topk_touch,
                                      similarity_topk_touch_ref)
from repro.serving.kv_cache import PagedKVCache


def _unit(rng, *shape):
    x = rng.normal(size=shape).astype(np.float32)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


class TestSimilarityKernel:
    @pytest.mark.parametrize("q,c,d", [(4, 32, 16), (128, 512, 64),
                                       (100, 1000, 48), (7, 513, 128),
                                       (1, 8, 256)])
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_matches_ref(self, q, c, d, dtype, nprng):
        qs = _unit(nprng, q, d)
        ks = _unit(nprng, c, d)
        ks[min(5, c - 1)] = qs[0]                     # guaranteed exact hit
        valid = nprng.random(c) > 0.3
        valid[min(5, c - 1)] = True
        qd, kd = jnp.asarray(qs, dtype), jnp.asarray(ks, dtype)
        i_ref, s_ref = similarity_lookup(qd, kd, jnp.asarray(valid), impl="ref")
        i_pal, s_pal = similarity_lookup(qd, kd, jnp.asarray(valid),
                                         impl="pallas_interpret",
                                         block_q=32, block_c=64)
        s_ref, s_pal = np.asarray(s_ref), np.asarray(s_pal)
        finite = np.isfinite(s_ref) & (s_ref > -1e29)
        np.testing.assert_allclose(s_ref[finite], s_pal[finite],
                                   rtol=2e-2, atol=2e-2)
        # ties may resolve differently; verify score at chosen index instead
        sc = qs @ ks.T
        sc[:, ~valid] = -np.inf
        chosen = sc[np.arange(q), np.asarray(i_pal)]
        np.testing.assert_allclose(chosen[finite], s_pal[finite],
                                   rtol=2e-2, atol=2e-2)

    def test_all_invalid_returns_neginf(self):
        q = jnp.ones((4, 16), jnp.float32) / 4.0
        k = jnp.ones((32, 16), jnp.float32) / 4.0
        valid = jnp.zeros((32,), bool)
        _, s = similarity_lookup(q, k, valid, impl="pallas_interpret",
                                 block_q=4, block_c=8)
        assert np.all(np.asarray(s) < -1e29)


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,k,d", [(1, 64, 4, 4, 16), (2, 128, 8, 2, 32),
                                           (1, 96, 4, 1, 64), (1, 64, 6, 3, 8)])
    @pytest.mark.parametrize("window", [0, 32])
    def test_matches_ref(self, b, s, h, k, d, window, nprng):
        q = nprng.normal(size=(b, s, h, d)).astype(np.float32)
        kk = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        v = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        o_ref = flash_attention(q, kk, v, causal=True, window=window, impl="ref")
        o_pal = flash_attention(q, kk, v, causal=True, window=window,
                                impl="pallas_interpret", block_q=32, block_kv=32)
        np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                                   np.asarray(o_pal, np.float32),
                                   rtol=2e-3, atol=2e-3)

    def test_bf16(self, nprng):
        b, s, h, k, d = 1, 64, 4, 2, 32
        q = jnp.asarray(nprng.normal(size=(b, s, h, d)), jnp.bfloat16)
        kk = jnp.asarray(nprng.normal(size=(b, s, k, d)), jnp.bfloat16)
        v = jnp.asarray(nprng.normal(size=(b, s, k, d)), jnp.bfloat16)
        o_ref = flash_attention(q, kk, v, impl="ref")
        o_pal = flash_attention(q, kk, v, impl="pallas_interpret",
                                block_q=32, block_kv=32)
        np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                                   np.asarray(o_pal, np.float32),
                                   rtol=3e-2, atol=3e-2)


class TestDecodeAttention:
    @pytest.mark.parametrize("b,s,h,k,d", [(2, 64, 4, 4, 16), (3, 100, 8, 2, 32),
                                           (1, 128, 4, 1, 64)])
    def test_matches_ref(self, b, s, h, k, d, nprng):
        q = nprng.normal(size=(b, h, d)).astype(np.float32)
        kk = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        v = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        kv_len = np.array([min(s, 7 + 13 * i) for i in range(b)], np.int32)
        o_ref = decode_attention(q, kk, v, kv_len, impl="ref")
        o_pal = decode_attention(q, kk, v, kv_len, impl="pallas_interpret",
                                 block_kv=32)
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                                   rtol=2e-3, atol=2e-3)

    def test_length_zero_safe(self, nprng):
        b, s, h, k, d = 1, 32, 2, 2, 8
        q = nprng.normal(size=(b, h, d)).astype(np.float32)
        kk = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        v = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        out = decode_attention(q, kk, v, np.zeros((b,), np.int32),
                               impl="pallas_interpret", block_kv=16)
        assert np.all(np.isfinite(np.asarray(out)))


def _paged_case(rng, *, B, page, n_pages, K, D, H=None, C=1, lengths=None,
                shared_pages=0, dtype=np.float32):
    """Build a pool + block tables the way PagedKVCache lays them out:
    rows map ceil(len / page) pages (first ``shared_pages`` of them shared
    across all rows — the prefix-index case), everything else INVALID."""
    H = H or K
    P = n_pages * B + 1                  # headroom: distinct pages per row
    if lengths is None:
        lengths = rng.integers(0, n_pages * page - C + 1, size=(B,))
    lengths = np.asarray(lengths, np.int32)
    kp = rng.normal(size=(P, page, K, D)).astype(dtype)
    vp = rng.normal(size=(P, page, K, D)).astype(dtype)
    bt = np.full((B, n_pages), PagedKVCache.INVALID, np.int32)
    nxt = shared_pages
    for b in range(B):
        used = -(-int(lengths[b] + C) // page)       # pages the row touches
        for j in range(min(used, n_pages)):
            if j < shared_pages:
                bt[b, j] = j
            else:
                bt[b, j] = nxt
                nxt += 1
    q = rng.normal(size=(B, C, H, D)).astype(dtype)
    return (jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(bt), jnp.asarray(lengths))


class TestPagedAttention:
    """Fused in-place paged attention vs the gather-path oracle (ref.py
    replicates ``_paged_view`` + the model's fp32-softmax GQA bit for bit,
    so ref-vs-interpret closeness here transfers to the serving path)."""

    @pytest.mark.parametrize("B,page,n_pages,K,H,D,C", [
        (3, 16, 4, 2, 4, 16, 1),       # GQA decode
        (2, 8, 6, 4, 4, 32, 1),        # MHA decode, ragged
        (2, 16, 4, 2, 8, 16, 8),       # chunked prefill, 4 q heads/group
        (1, 32, 2, 1, 2, 64, 16),      # single row, wide chunk
    ])
    def test_matches_gather_oracle(self, B, page, n_pages, K, H, D, C, nprng):
        q, kp, vp, bt, ln = _paged_case(nprng, B=B, page=page,
                                        n_pages=n_pages, K=K, H=H, D=D, C=C)
        o_ref = paged_attention(q, kp, vp, bt, ln, impl="ref")
        o_pal = paged_attention(q, kp, vp, bt, ln, impl="pallas_interpret")
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                                   rtol=2e-3, atol=2e-3)

    def test_partial_last_page_and_page_boundary(self, nprng):
        """Rows sitting mid-page, exactly on a page boundary, and at 0."""
        q, kp, vp, bt, ln = _paged_case(nprng, B=4, page=16, n_pages=4, K=2,
                                        H=4, D=16, lengths=[5, 16, 32, 0])
        o_ref = paged_attention(q, kp, vp, bt, ln, impl="ref")
        o_pal = paged_attention(q, kp, vp, bt, ln, impl="pallas_interpret")
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                                   rtol=2e-3, atol=2e-3)

    def test_idle_all_invalid_row_is_finite(self, nprng):
        """An idle decode slot rides the dispatch with an all-INVALID table
        row; the kernel must finalize it to exact zeros, not NaN."""
        q, kp, vp, bt, ln = _paged_case(nprng, B=3, page=16, n_pages=3, K=2,
                                        H=4, D=16, lengths=[20, 0, 7])
        bt = bt.at[1].set(PagedKVCache.INVALID)
        out = np.asarray(paged_attention(q, kp, vp, bt, ln,
                                         impl="pallas_interpret"))
        assert np.all(np.isfinite(out))
        np.testing.assert_array_equal(out[1], 0.0)

    def test_shared_prefix_pages(self, nprng):
        """Cross-user shared prefix pages: rows alias physical pages."""
        q, kp, vp, bt, ln = _paged_case(nprng, B=4, page=8, n_pages=6, K=2,
                                        H=4, D=16, shared_pages=2,
                                        lengths=[30, 22, 17, 40])
        assert np.array_equal(np.asarray(bt)[:, :2],
                              np.tile([[0, 1]], (4, 1)))
        o_ref = paged_attention(q, kp, vp, bt, ln, impl="ref")
        o_pal = paged_attention(q, kp, vp, bt, ln, impl="pallas_interpret")
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                                   rtol=2e-3, atol=2e-3)

    def test_bf16(self, nprng):
        q, kp, vp, bt, ln = _paged_case(nprng, B=2, page=16, n_pages=3, K=2,
                                        H=4, D=32, dtype=np.float32)
        q, kp, vp = (x.astype(jnp.bfloat16) for x in (q, kp, vp))
        o_ref = paged_attention(q, kp, vp, bt, ln, impl="ref")
        o_pal = paged_attention(q, kp, vp, bt, ln, impl="pallas_interpret")
        np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                                   np.asarray(o_pal, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_auto_routes_to_ref_off_tpu(self, nprng):
        """CI has no TPU: auto must be the jnp oracle, bit for bit."""
        q, kp, vp, bt, ln = _paged_case(nprng, B=2, page=16, n_pages=3, K=2,
                                        H=4, D=16)
        if jax.default_backend() == "tpu":
            pytest.skip("auto routes to the real kernel on TPU")
        np.testing.assert_array_equal(
            np.asarray(paged_attention(q, kp, vp, bt, ln, impl="auto")),
            np.asarray(paged_attention(q, kp, vp, bt, ln, impl="ref")))

    def test_seeded_property_sweep(self, nprng):
        """Seeded stand-in for the hypothesis sweep: random ragged lengths,
        INVALID rows, shared prefixes, chunk widths."""
        for trial in range(8):
            B = int(nprng.integers(1, 5))
            page = int(nprng.choice([8, 16]))
            n_pages = int(nprng.integers(2, 6))
            K = int(nprng.choice([1, 2, 4]))
            H = K * int(nprng.choice([1, 2, 4]))
            C = int(nprng.choice([1, 1, 4, 8]))
            q, kp, vp, bt, ln = _paged_case(
                nprng, B=B, page=page, n_pages=n_pages, K=K, H=H, D=16, C=C,
                shared_pages=int(nprng.integers(0, 2)))
            o_ref = paged_attention(q, kp, vp, bt, ln, impl="ref")
            o_pal = paged_attention(q, kp, vp, bt, ln,
                                    impl="pallas_interpret")
            np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                                       rtol=2e-3, atol=2e-3,
                                       err_msg=f"trial {trial}")

    def test_hypothesis_sweep(self, nprng):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=15, deadline=None)
        @given(B=st.integers(1, 4), page=st.sampled_from([8, 16]),
               n_pages=st.integers(2, 5), K=st.sampled_from([1, 2, 4]),
               G=st.sampled_from([1, 2, 4]), C=st.sampled_from([1, 4, 8]),
               seed=st.integers(0, 2**31 - 1))
        def check(B, page, n_pages, K, G, C, seed):
            rng = np.random.default_rng(seed)
            q, kp, vp, bt, ln = _paged_case(rng, B=B, page=page,
                                            n_pages=n_pages, K=K, H=K * G,
                                            D=16, C=C)
            o_ref = paged_attention(q, kp, vp, bt, ln, impl="ref")
            o_pal = paged_attention(q, kp, vp, bt, ln,
                                    impl="pallas_interpret")
            np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                                       rtol=2e-3, atol=2e-3)

        check()

    def test_byte_model(self):
        """The benchmark/docs byte model: in-place strictly below gather for
        any non-empty batch, and exactly the mapped-page traffic."""
        kv = np.array([100, 0, 17, 512])
        kw = dict(page_size=16, max_len=512, kv_heads=8, head_dim=32,
                  dtype_bytes=4)
        g = attention_kv_bytes_per_step(kv, impl="gather", **kw)
        p = attention_kv_bytes_per_step(kv, impl="paged", **kw)
        mapped = sum(-(-int(x) // 16) * 16 for x in kv)
        row = 2 * 8 * 32 * 4
        assert p == mapped * row
        assert g == (mapped + 2 * 4 * 512) * row
        assert p < g
        with pytest.raises(ValueError):
            attention_kv_bytes_per_step(kv, impl="nope", **kw)


class TestFusedTopkTouch:
    """Fused top-k + LRU-touch epilogue vs the unfused oracle."""

    def _case(self, rng, Q, C, D):
        q = _unit(rng, Q, D)
        ks = _unit(rng, C, D)
        # exact hits incl. two queries hitting the SAME slot (multiplicity)
        ks[3] = q[0]
        ks[11 % C] = q[1]
        if Q > 2:
            q[2] = q[0]
        valid = rng.random(C) > 0.3
        valid[[3, 11 % C]] = True
        lu = rng.integers(0, 50, C).astype(np.int32)
        fr = rng.integers(0, 50, C).astype(np.int32)
        return (jnp.asarray(q), jnp.asarray(ks), jnp.asarray(valid),
                jnp.asarray(lu), jnp.asarray(fr), jnp.asarray(np.int32(99)))

    @pytest.mark.parametrize("Q,C,D,k", [(8, 64, 16, 4), (5, 100, 32, 1),
                                         (16, 48, 16, 8)])
    def test_matches_unfused_oracle(self, Q, C, D, k, nprng):
        q, ks, valid, lu, fr, clock = self._case(nprng, Q, C, D)
        r_ref = similarity_topk_touch(q, ks, valid, k, lu, fr, clock,
                                      threshold=0.98, impl="ref")
        r_pal = similarity_topk_touch(q, ks, valid, k, lu, fr, clock,
                                      threshold=0.98, impl="pallas_interpret",
                                      block_c=16)
        for a, b, name in zip(r_ref, r_pal, ("idx", "score", "lu", "fr")):
            if name == "score":
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=2e-2, atol=2e-2)
            else:
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                              err_msg=name)

    def test_mask_rows_never_touch(self, nprng):
        q, ks, valid, lu, fr, clock = self._case(nprng, 8, 64, 16)
        mask = jnp.asarray(np.array([1, 0, 1, 1, 0, 1, 1, 1], bool))
        for impl in ("ref", "pallas_interpret"):
            _, _, lu2, fr2 = similarity_topk_touch(
                q, ks, valid, 2, lu, fr, clock, threshold=0.98, mask=mask,
                impl=impl, block_c=16)
            # query 1's exact hit is masked: its slot must be untouched
            assert int(fr2[11 % 64]) == int(fr[11 % 64]), impl
            # query 0 and its duplicate query 2 both touch slot 3
            assert int(fr2[3]) == int(fr[3]) + 2, impl

    def test_touch_semantics_match_apply_probe(self, nprng):
        """End-to-end: SemanticCache with fuse_touch=True transitions state
        exactly like the unfused lookup + apply_probe path."""
        import dataclasses

        from repro.core.semantic_cache import SemanticCache

        C, D, P, Q = 48, 16, 4, 8
        base = SemanticCache(capacity=C, key_dim=D, payload_dim=P,
                             threshold=0.9)
        st0 = base.init()
        ks = _unit(nprng, C, D)
        st0 = base.insert(st0, jnp.asarray(ks[:30]),
                          jnp.asarray(nprng.normal(size=(30, P)),
                                      jnp.float32))
        q = _unit(nprng, Q, D)
        q[0] = ks[3]
        q[1] = ks[3]
        q[2] = ks[7]
        mask = np.ones(Q, bool)
        mask[5] = False
        for impl in ("ref", "pallas_interpret"):
            fused = dataclasses.replace(base, fuse_touch=True,
                                        lookup_impl=impl)
            s1, r1 = base.lookup(st0, jnp.asarray(q), mask=jnp.asarray(mask))
            s2, r2 = fused.lookup(st0, jnp.asarray(q), mask=jnp.asarray(mask))
            np.testing.assert_array_equal(np.asarray(r1.hit),
                                          np.asarray(r2.hit), err_msg=impl)
            np.testing.assert_array_equal(np.asarray(r1.value),
                                          np.asarray(r2.value), err_msg=impl)
            for f in ("last_used", "freq", "clock", "hits", "misses",
                      "valid"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(s1, f)), np.asarray(getattr(s2, f)),
                    err_msg=f"{impl}:{f}")


class TestKernelVsModelAttention:
    def test_flash_equals_model_xla_path(self, nprng):
        """The kernel and the model's XLA attention implement the same op."""
        from repro.models import layers as L

        b, s, h, k, d = 2, 64, 4, 2, 16
        q = nprng.normal(size=(b, s, h, d)).astype(np.float32)
        kk = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        v = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        model_out = L.causal_attention(jnp.asarray(q), jnp.asarray(kk),
                                       jnp.asarray(v), pos, pos, causal=True)
        kern_out = flash_attention(q, kk, v, causal=True,
                                   impl="pallas_interpret",
                                   block_q=32, block_kv=32)
        np.testing.assert_allclose(np.asarray(model_out), np.asarray(kern_out),
                                   rtol=2e-3, atol=2e-3)
