"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs the ref.py
pure-jnp oracle.  interpret mode executes the kernel body in Python on CPU,
validating BlockSpec indexing, online-softmax math and masking."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.similarity import similarity_lookup


def _unit(rng, *shape):
    x = rng.normal(size=shape).astype(np.float32)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


class TestSimilarityKernel:
    @pytest.mark.parametrize("q,c,d", [(4, 32, 16), (128, 512, 64),
                                       (100, 1000, 48), (7, 513, 128),
                                       (1, 8, 256)])
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_matches_ref(self, q, c, d, dtype, nprng):
        qs = _unit(nprng, q, d)
        ks = _unit(nprng, c, d)
        ks[min(5, c - 1)] = qs[0]                     # guaranteed exact hit
        valid = nprng.random(c) > 0.3
        valid[min(5, c - 1)] = True
        qd, kd = jnp.asarray(qs, dtype), jnp.asarray(ks, dtype)
        i_ref, s_ref = similarity_lookup(qd, kd, jnp.asarray(valid), impl="ref")
        i_pal, s_pal = similarity_lookup(qd, kd, jnp.asarray(valid),
                                         impl="pallas_interpret",
                                         block_q=32, block_c=64)
        s_ref, s_pal = np.asarray(s_ref), np.asarray(s_pal)
        finite = np.isfinite(s_ref) & (s_ref > -1e29)
        np.testing.assert_allclose(s_ref[finite], s_pal[finite],
                                   rtol=2e-2, atol=2e-2)
        # ties may resolve differently; verify score at chosen index instead
        sc = qs @ ks.T
        sc[:, ~valid] = -np.inf
        chosen = sc[np.arange(q), np.asarray(i_pal)]
        np.testing.assert_allclose(chosen[finite], s_pal[finite],
                                   rtol=2e-2, atol=2e-2)

    def test_all_invalid_returns_neginf(self):
        q = jnp.ones((4, 16), jnp.float32) / 4.0
        k = jnp.ones((32, 16), jnp.float32) / 4.0
        valid = jnp.zeros((32,), bool)
        _, s = similarity_lookup(q, k, valid, impl="pallas_interpret",
                                 block_q=4, block_c=8)
        assert np.all(np.asarray(s) < -1e29)


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,k,d", [(1, 64, 4, 4, 16), (2, 128, 8, 2, 32),
                                           (1, 96, 4, 1, 64), (1, 64, 6, 3, 8)])
    @pytest.mark.parametrize("window", [0, 32])
    def test_matches_ref(self, b, s, h, k, d, window, nprng):
        q = nprng.normal(size=(b, s, h, d)).astype(np.float32)
        kk = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        v = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        o_ref = flash_attention(q, kk, v, causal=True, window=window, impl="ref")
        o_pal = flash_attention(q, kk, v, causal=True, window=window,
                                impl="pallas_interpret", block_q=32, block_kv=32)
        np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                                   np.asarray(o_pal, np.float32),
                                   rtol=2e-3, atol=2e-3)

    def test_bf16(self, nprng):
        b, s, h, k, d = 1, 64, 4, 2, 32
        q = jnp.asarray(nprng.normal(size=(b, s, h, d)), jnp.bfloat16)
        kk = jnp.asarray(nprng.normal(size=(b, s, k, d)), jnp.bfloat16)
        v = jnp.asarray(nprng.normal(size=(b, s, k, d)), jnp.bfloat16)
        o_ref = flash_attention(q, kk, v, impl="ref")
        o_pal = flash_attention(q, kk, v, impl="pallas_interpret",
                                block_q=32, block_kv=32)
        np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                                   np.asarray(o_pal, np.float32),
                                   rtol=3e-2, atol=3e-2)


class TestDecodeAttention:
    @pytest.mark.parametrize("b,s,h,k,d", [(2, 64, 4, 4, 16), (3, 100, 8, 2, 32),
                                           (1, 128, 4, 1, 64)])
    def test_matches_ref(self, b, s, h, k, d, nprng):
        q = nprng.normal(size=(b, h, d)).astype(np.float32)
        kk = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        v = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        kv_len = np.array([min(s, 7 + 13 * i) for i in range(b)], np.int32)
        o_ref = decode_attention(q, kk, v, kv_len, impl="ref")
        o_pal = decode_attention(q, kk, v, kv_len, impl="pallas_interpret",
                                 block_kv=32)
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                                   rtol=2e-3, atol=2e-3)

    def test_length_zero_safe(self, nprng):
        b, s, h, k, d = 1, 32, 2, 2, 8
        q = nprng.normal(size=(b, h, d)).astype(np.float32)
        kk = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        v = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        out = decode_attention(q, kk, v, np.zeros((b,), np.int32),
                               impl="pallas_interpret", block_kv=16)
        assert np.all(np.isfinite(np.asarray(out)))


class TestKernelVsModelAttention:
    def test_flash_equals_model_xla_path(self, nprng):
        """The kernel and the model's XLA attention implement the same op."""
        from repro.models import layers as L

        b, s, h, k, d = 2, 64, 4, 2, 16
        q = nprng.normal(size=(b, s, h, d)).astype(np.float32)
        kk = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        v = nprng.normal(size=(b, s, k, d)).astype(np.float32)
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        model_out = L.causal_attention(jnp.asarray(q), jnp.asarray(kk),
                                       jnp.asarray(v), pos, pos, causal=True)
        kern_out = flash_attention(q, kk, v, causal=True,
                                   impl="pallas_interpret",
                                   block_q=32, block_kv=32)
        np.testing.assert_allclose(np.asarray(model_out), np.asarray(kern_out),
                                   rtol=2e-3, atol=2e-3)
