"""Training loop: loss goes down, microbatching is exact, straggler watch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.data.pipeline import SyntheticLMData
from repro.models import build_model
from repro.train.trainer import (StragglerWatch, TrainerConfig,
                                 init_train_state, make_train_step)


def test_loss_decreases_on_small_model(rng):
    cfg = reduced_config(get_config("llama32_1b"))
    model = build_model(cfg)
    tcfg = TrainerConfig(peak_lr=3e-3, warmup_steps=5, total_steps=60)
    state = init_train_state(model, rng, tcfg)
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    losses = []
    for i in range(40):
        state, metrics = step(state, data.batch_at(i % 4))  # cycle 4 batches
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses[::8]


def test_microbatch_equivalence(rng):
    """k=1 vs k=2 grad accumulation: same step result (mean-of-grads)."""
    cfg = reduced_config(get_config("llama32_1b"))
    model = build_model(cfg)
    # fp32 compute: tests the accumulation MATH exactly (bf16 reduction-order
    # noise gets amplified by Adam's per-param normalization otherwise)
    t1 = TrainerConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10,
                       microbatches=1, compute_dtype="float32")
    t2 = dataclasses.replace(t1, microbatches=2)
    s1 = init_train_state(model, rng, t1)
    s2 = jax.tree.map(jnp.copy, s1)
    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    batch = data.batch_at(0)
    s1, m1 = jax.jit(make_train_step(model, t1))(s1, batch)
    s2, m2 = jax.jit(make_train_step(model, t2))(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    for k in s1.params:
        np.testing.assert_allclose(np.asarray(s1.params[k], np.float32),
                                   np.asarray(s2.params[k], np.float32),
                                   rtol=1e-3, atol=1e-5)


def test_chunked_ce_equals_dense(rng):
    """cfg.loss_chunk: chunked cross-entropy must match the dense loss and
    gradients exactly (it's the same math, streamed)."""
    import dataclasses

    cfg0 = dataclasses.replace(reduced_config(get_config("llama32_1b")),
                               dtype="float32")
    cfg1 = dataclasses.replace(cfg0, loss_chunk=8)
    m0, m1 = build_model(cfg0), build_model(cfg1)
    params = m0.init(rng)
    toks = np.random.default_rng(0).integers(
        0, cfg0.vocab_size, size=(2, 33)).astype(np.int32)
    (l0, _), g0 = jax.value_and_grad(m0.loss, has_aux=True)(params, {"tokens": toks})
    (l1, _), g1 = jax.value_and_grad(m1.loss, has_aux=True)(params, {"tokens": toks})
    assert float(l0) == pytest.approx(float(l1), rel=1e-6)
    for k in g0:
        np.testing.assert_allclose(np.asarray(g0[k]), np.asarray(g1[k]),
                                   rtol=1e-4, atol=1e-6)


def test_straggler_watch_flags_slow_steps():
    w = StragglerWatch(ratio=2.0, alpha=0.5)
    for i in range(10):
        assert not w.observe(i, 0.1)
    assert w.observe(10, 1.0)                      # 10x the EWMA
    assert len(w.events) == 1
    assert w.events[0][0] == 10


def test_data_pipeline_determinism_and_host_sharding():
    d0 = SyntheticLMData(vocab_size=100, seq_len=16, global_batch=8,
                         num_hosts=2, host_id=0)
    d0b = SyntheticLMData(vocab_size=100, seq_len=16, global_batch=8,
                          num_hosts=2, host_id=0)
    d1 = SyntheticLMData(vocab_size=100, seq_len=16, global_batch=8,
                         num_hosts=2, host_id=1)
    a = d0.batch_at(3)["tokens"]
    np.testing.assert_array_equal(a, d0b.batch_at(3)["tokens"])  # deterministic
    assert a.shape == (4, 16)                                    # host slice
    assert not np.array_equal(a, d1.batch_at(3)["tokens"])       # disjoint
    assert not np.array_equal(a, d0.batch_at(4)["tokens"])       # per-step
