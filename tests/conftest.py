import os
import sys

# Make src/ importable when PYTHONPATH isn't set
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _pin_global_seed():
    """Flaky-proofing: every test starts from the same legacy-global numpy
    seed, so any code path that accidentally reaches ``np.random.*``
    (instead of an explicit seeded Generator) is still deterministic
    run-to-run and independent of test execution order."""
    np.random.seed(0)
    yield


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def nprng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def tiny_model():
    """coic-paper scale model + params, shared across tests."""
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("coic-paper")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params
