"""Mamba-2 SSD: the chunked scan must equal the naive per-step recurrence,
for any chunk size, and the decode step must continue the state exactly."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd_chunked


def naive_ssd(x, dt, a, b, c):
    """Direct recurrence oracle: h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t."""
    B_, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    h = np.zeros((B_, H, P, N), np.float64)
    ys = np.zeros((B_, L, H, P), np.float64)
    for t in range(L):
        decay = np.exp(dt[:, t, :] * a[None, :])               # (B,H)
        bt = np.repeat(b[:, t], rep, axis=1)                   # (B,H,N)
        ct = np.repeat(c[:, t], rep, axis=1)
        h = h * decay[..., None, None] + np.einsum(
            "bh,bhp,bhn->bhpn", dt[:, t], x[:, t].astype(np.float64), bt)
        ys[:, t] = np.einsum("bhpn,bhn->bhp", h, ct)
    return ys, h


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_equals_naive(chunk, nprng):
    B_, L, H, P, G, N = 2, 16, 4, 8, 2, 8
    x = nprng.standard_normal((B_, L, H, P)).astype(np.float32)
    dt = np.abs(nprng.standard_normal((B_, L, H))).astype(np.float32) * 0.5
    a = -np.abs(nprng.standard_normal(H)).astype(np.float32)
    b = nprng.standard_normal((B_, L, G, N)).astype(np.float32)
    c = nprng.standard_normal((B_, L, G, N)).astype(np.float32)

    y_ref, h_ref = naive_ssd(x, dt, a, b, c)
    y, h = ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(a),
                       jnp.asarray(b), jnp.asarray(c), chunk)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=2e-4, atol=2e-4)


def test_chunk_size_invariance(nprng):
    B_, L, H, P, G, N = 1, 24, 2, 4, 1, 4
    x = nprng.standard_normal((B_, L, H, P)).astype(np.float32)
    dt = np.abs(nprng.standard_normal((B_, L, H))).astype(np.float32) * 0.3
    a = -np.abs(nprng.standard_normal(H)).astype(np.float32)
    b = nprng.standard_normal((B_, L, G, N)).astype(np.float32)
    c = nprng.standard_normal((B_, L, G, N)).astype(np.float32)
    outs = []
    for chunk in (4, 6, 12, 24):
        y, h = ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(a),
                           jnp.asarray(b), jnp.asarray(c), chunk)
        outs.append((np.asarray(y), np.asarray(h)))
    for y, h in outs[1:]:
        np.testing.assert_allclose(y, outs[0][0], rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(h, outs[0][1], rtol=2e-4, atol=2e-4)


def test_initial_state_continuation(nprng):
    """ssd(x, h0=ssd(x1).h) == ssd([x1; x2]) on the second half."""
    B_, L, H, P, G, N = 1, 16, 2, 4, 1, 4
    def mk(*s):
        return nprng.standard_normal(s).astype(np.float32)
    x = mk(B_, L, H, P)
    dt = np.abs(mk(B_, L, H)) * 0.4
    a = -np.abs(mk(H))
    b = mk(B_, L, G, N)
    c = mk(B_, L, G, N)
    def j(v):
        return jnp.asarray(v)
    y_full, h_full = ssd_chunked(j(x), j(dt), j(a), j(b), j(c), 8)
    half = L // 2
    y1, h1 = ssd_chunked(j(x[:, :half]), j(dt[:, :half]), j(a),
                         j(b[:, :half]), j(c[:, :half]), 8)
    y2, h2 = ssd_chunked(j(x[:, half:]), j(dt[:, half:]), j(a),
                         j(b[:, half:]), j(c[:, half:]), 8, h0=h1)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, half:]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)
