"""Cross-cluster federation tier: digest-probe exactness, remote-rung
serving, digest staleness (false hits fall through, never phantom
payloads), fresh-digest brute-force equivalence, freq-weighted admission,
peer-aware eviction, and engine-level dispatch bounds.

Seeded-random sequences run directly (no ``hypothesis`` dependency — the
container may not ship it); ``test_federation_properties.py`` holds the
hypothesis variants."""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.cluster import ClusterConfig, CooperativeEdgeCluster
from repro.core.federation import (TIER_LOCAL, TIER_MISS, TIER_PEER,
                                   TIER_REMOTE, FederatedEdgeTier,
                                   FederationConfig)
from repro.core.policies import EvictionPolicy
from repro.core.semantic_cache import SemanticCache
from repro.data.workload import RoamingWorkload
from repro.parallel.sharding import federated_digest_lookup


def _unit(rng, n, d):
    x = rng.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _fed(clusters=2, nodes=2, cap=16, d=32, p=4, tau=0.9,
         digest_size=64, digest_interval=1, admission="always",
         share=True, policy=EvictionPolicy("lru")):
    return FederatedEdgeTier(FederationConfig(
        num_clusters=clusters, digest_size=digest_size,
        digest_interval=digest_interval, share=share,
        cluster=ClusterConfig(num_nodes=nodes, node_capacity=cap, key_dim=d,
                              payload_dim=p, threshold=tau, policy=policy,
                              admission=admission)))


# ---------------------------------------------------------------------------
# the grouped digest probe: one dispatch, home cluster excluded
# ---------------------------------------------------------------------------


class TestDigestLookup:
    @pytest.mark.parametrize("k_cl,m,b,d", [(2, 8, 4, 16), (4, 16, 7, 32),
                                            (3, 5, 1, 8)])
    def test_matches_home_masked_oracle(self, k_cl, m, b, d):
        """Row (h, q) must match a numpy top-1 over the pooled digest
        matrix with home cluster h's rows masked out (scores to fp32
        tolerance, and the returned index must be a valid non-home row
        scoring at the max)."""
        rng = np.random.default_rng(k_cl * 100 + m)
        digests = _unit(rng, k_cl * m, d).reshape(k_cl, m, d)
        queries = _unit(rng, k_cl * b, d).reshape(k_cl, b, d)
        valid = rng.random((k_cl, m)) > 0.3
        gi, gs = federated_digest_lookup(
            jnp.asarray(queries), jnp.asarray(digests), jnp.asarray(valid), 1)
        gi, gs = np.asarray(gi)[..., 0], np.asarray(gs)[..., 0]
        pooled = digests.reshape(k_cl * m, d)
        for h in range(k_cl):
            v = valid.copy()
            v[h] = False
            scores = pooled @ queries[h].T                 # (K*M, B)
            scores[~v.reshape(-1)] = -np.inf
            best = scores.max(axis=0)
            np.testing.assert_allclose(gs[h], best, rtol=1e-5, atol=1e-5)
            for q in range(b):
                idx = int(gi[h, q])
                assert idx // m != h                       # never the home
                assert v.reshape(-1)[idx]
                assert scores[idx, q] >= best[q] - 1e-5

    def test_home_digest_never_wins(self):
        """A query whose exact key sits only in the HOME digest must not
        match it — the home cluster was already scanned authoritatively."""
        rng = np.random.default_rng(0)
        d = 16
        key = _unit(rng, 1, d)[0]
        digests = np.zeros((2, 4, d), np.float32)
        digests[0, 0] = key                      # home cluster 0 advertises it
        valid = np.zeros((2, 4), bool)
        valid[0, 0] = True
        q = np.zeros((2, 1, d), np.float32)
        q[0, 0] = key
        _, gs = federated_digest_lookup(jnp.asarray(q), jnp.asarray(digests),
                                        jnp.asarray(valid), 1)
        assert float(gs[0, 0, 0]) < -1e29        # nothing valid to match


# ---------------------------------------------------------------------------
# remote rung: serve, admit, count — and staleness handling
# ---------------------------------------------------------------------------


class TestRemoteRung:
    def test_remote_hit_then_admitted_locally(self):
        rng = np.random.default_rng(1)
        d, p = 32, 4
        pool = _unit(rng, 8, d)
        pay = rng.standard_normal((8, p)).astype(np.float32)
        fed = _fed(clusters=3, nodes=2, d=d, p=p)
        fed.insert(0, 0, jnp.asarray(pool), jnp.asarray(pay))

        res = fed.lookup(1, 1, pool)
        assert (res.tier == TIER_REMOTE).all(), res.tier
        assert (res.cluster == 0).all()
        np.testing.assert_allclose(res.value, pay, rtol=1e-5)
        assert fed.last_ladder_dispatches <= 4

        res2 = fed.lookup(1, 1, pool)            # admitted into (1, 1)
        assert (res2.tier == TIER_LOCAL).all(), res2.tier
        st = fed.stats()
        assert st["tier_counts"]["remote"] == 8
        assert st["clusters"][0]["remote_hits_served"] == 8
        assert st["clusters"][1]["remote_fills"] == 8

    def test_share_off_keeps_clusters_isolated(self):
        rng = np.random.default_rng(2)
        d = 32
        keys = _unit(rng, 4, d)
        for share, want in ((True, True), (False, False)):
            fed = _fed(clusters=2, share=share, d=d)
            fed.insert(0, 0, jnp.asarray(keys),
                       jnp.ones((4, 4), jnp.float32))
            res = fed.lookup(1, 0, keys)
            assert bool(res.hit.all()) == want

    def test_stale_digest_false_hit_falls_through_to_cloud(self):
        """A digest row whose entry was evicted since the refresh matches
        the probe but fails the authoritative confirm: counted as a digest
        false hit, served as a MISS with a zero payload — stale digests
        cost a wasted probe, never a phantom payload."""
        rng = np.random.default_rng(3)
        d, p = 32, 4
        e, f = _unit(rng, 2, d)
        fed = _fed(clusters=2, nodes=1, cap=1, d=d, p=p,
                   digest_interval=100, admission="never")
        fed.insert(0, 0, jnp.asarray(e[None]),
                   jnp.full((1, p), 7.0, jnp.float32))

        res = fed.lookup(1, 0, e[None])          # digest fresh at step 0
        assert res.tier[0] == TIER_REMOTE
        # evict E: the only slot now holds F, digest still advertises E
        fed.insert(0, 0, jnp.asarray(f[None]),
                   jnp.full((1, p), 9.0, jnp.float32))
        res2 = fed.lookup(1, 0, e[None])
        assert res2.tier[0] == TIER_MISS
        assert not res2.hit[0]
        np.testing.assert_array_equal(res2.value[0], np.zeros(p))
        assert fed.digest_false_hits == 1

    def test_undersized_digest_under_reports_only(self):
        """digest_size=1 advertises just the hottest entry: colder remote
        entries become misses (under-report), never wrong payloads."""
        rng = np.random.default_rng(4)
        d, p = 32, 4
        pool = _unit(rng, 4, d)
        pay = rng.standard_normal((4, p)).astype(np.float32)
        fed = _fed(clusters=2, nodes=1, d=d, p=p, digest_size=1,
                   admission="never")
        fed.insert(0, 0, jnp.asarray(pool), jnp.asarray(pay))
        # heat up entry 2: local hits at its home cluster
        for _ in range(3):
            r = fed.lookup(0, 0, pool[2:3])
            assert r.tier[0] == TIER_LOCAL
        res = fed.lookup(1, 0, pool)
        assert res.tier[2] == TIER_REMOTE        # the advertised hot entry
        np.testing.assert_allclose(res.value[2], pay[2], rtol=1e-5)
        others = [i for i in range(4) if i != 2]
        assert (res.tier[others] == TIER_MISS).all()
        assert fed.digest_false_hits == 0        # under-report, not phantom


# ---------------------------------------------------------------------------
# fresh digests == brute-force probing every cluster (seeded property)
# ---------------------------------------------------------------------------


def _oracle_ladder(fed, queries, mask):
    """Numpy ladder over the pre-lookup state snapshot: local -> peer ->
    remote (brute-force over every OTHER cluster's pooled shards)."""
    ccfg = fed.cfg.cluster
    K, N, B, _ = queries.shape
    keys = np.stack([
        np.stack([np.asarray(s.keys) for s in cl.states])
        for cl in fed.clusters])                            # (K, N, C, D)
    valid = np.stack([
        np.stack([np.asarray(s.valid) for s in cl.states])
        for cl in fed.clusters])                            # (K, N, C)
    tier = np.full((K, N, B), TIER_MISS, np.int8)
    for k in range(K):
        for n in range(N):
            for b in range(B):
                if not mask[k, n, b]:
                    continue
                q = queries[k, n, b]
                def best(kk, vv):
                    s = kk.reshape(-1, kk.shape[-1]) @ q
                    s[~vv.reshape(-1)] = -np.inf
                    return s.max() if vv.any() else -np.inf
                if best(keys[k, n], valid[k, n]) >= ccfg.threshold:
                    tier[k, n, b] = TIER_LOCAL
                elif best(keys[k], valid[k]) >= ccfg.threshold:
                    tier[k, n, b] = TIER_PEER
                else:
                    others = [c for c in range(K) if c != k]
                    if best(keys[others], valid[others]) >= ccfg.threshold:
                        tier[k, n, b] = TIER_REMOTE
    return tier


@pytest.mark.parametrize("seed", range(4))
def test_fresh_full_digest_equals_brute_force_every_cluster(seed):
    """With digest_interval=1 and a digest wide enough to carry every live
    entry, the digest rung is hit-for-hit equivalent to brute-force probing
    every remote cluster: same tiers, same payloads, zero false hits."""
    rng = np.random.default_rng(seed)
    K, N, cap, d, p, tau = 3, 2, 8, 32, 4, 0.8
    pool = _unit(rng, 20, d)
    pay = rng.standard_normal((20, p)).astype(np.float32)
    fed = _fed(clusters=K, nodes=N, cap=cap, d=d, p=p, tau=tau,
               digest_size=N * cap, digest_interval=1)

    for _ in range(12):
        B = int(rng.integers(1, 4))
        qids = rng.integers(0, 20, size=(K, N, B))
        queries = pool[qids]
        mask = rng.random((K, N, B)) > 0.2
        want = _oracle_ladder(fed, queries, mask)
        res = fed.lookup_grouped(queries, mask)
        assert np.array_equal(res.tier[mask], want[mask]), (
            res.tier[mask], want[mask])
        served = res.hit & mask
        if served.any():
            np.testing.assert_allclose(res.value[served],
                                       pay[qids[served]], rtol=1e-5)
        # insert cloud results for misses at their home node
        miss = (res.tier == TIER_MISS) & mask
        for k in range(K):
            for n in range(N):
                rows = np.nonzero(miss[k, n])[0]
                if rows.size:
                    fed.insert(k, n, jnp.asarray(queries[k, n, rows]),
                               jnp.asarray(pay[qids[k, n, rows]]))
    assert fed.digest_false_hits == 0            # fresh digests never lie
    assert fed.stats()["tier_counts"]["remote"] > 0


# ---------------------------------------------------------------------------
# freq-weighted admission
# ---------------------------------------------------------------------------


class TestFreqWeightedAdmission:
    def test_cold_entry_not_admitted_over_hotter_victims(self):
        """A peer entry with 1 observed hit must not displace local entries
        with 2+; once its owner-side count beats the coldest local victim
        it replicates."""
        rng = np.random.default_rng(5)
        d, p = 32, 4
        pool = _unit(rng, 6, d)
        cl = CooperativeEdgeCluster(ClusterConfig(
            num_nodes=2, node_capacity=4, key_dim=d, payload_dim=p,
            threshold=0.9, admission="freq_weighted"))
        # node 0: full shard, every entry hit twice (freq >= 3)
        cl.insert(0, jnp.asarray(pool[:4]), jnp.zeros((4, p), jnp.float32))
        for _ in range(2):
            assert bool(cl.lookup(0, jnp.asarray(pool[:4])).hit.all())
        # node 1 owns E (freq 1 at insert)
        cl.insert(1, jnp.asarray(pool[4:5]), jnp.ones((1, p), jnp.float32))

        r = cl.lookup(0, jnp.asarray(pool[4:5]))         # peer hit, freq 1
        assert r.tier[0] == 1 and cl.peer_fills[0] == 0  # not admitted
        # each serve touches the owner: freq climbs; once it beats the
        # coldest local victim's count the entry replicates
        for _ in range(8):
            cl.lookup(0, jnp.asarray(pool[4:5]))
            if cl.peer_fills[0]:
                break
        assert cl.peer_fills[0] == 1

    def test_admits_into_free_slots(self):
        """An empty requester shard always admits (victim count 0)."""
        rng = np.random.default_rng(6)
        d = 32
        keys = _unit(rng, 2, d)
        cl = CooperativeEdgeCluster(ClusterConfig(
            num_nodes=2, node_capacity=4, key_dim=d, payload_dim=4,
            threshold=0.9, admission="freq_weighted"))
        cl.insert(1, jnp.asarray(keys), jnp.ones((2, 4), jnp.float32))
        cl.lookup(0, jnp.asarray(keys))
        assert cl.peer_fills[0] == 2

    def test_remote_admission_inherits_freq_weighted(self):
        rng = np.random.default_rng(7)
        d, p = 32, 4
        pool = _unit(rng, 5, d)
        fed = _fed(clusters=2, nodes=1, cap=4, d=d, p=p,
                   admission="freq_weighted")
        fed.insert(0, 0, jnp.asarray(pool[:1]),
                   jnp.ones((1, p), jnp.float32))
        # requester's shard is empty -> admit on first remote hit
        res = fed.lookup(1, 0, pool[:1])
        assert res.tier[0] == TIER_REMOTE
        assert fed.remote_fills[1] == 1
        assert fed.lookup(1, 0, pool[:1]).tier[0] == TIER_LOCAL


# ---------------------------------------------------------------------------
# peer-aware eviction
# ---------------------------------------------------------------------------


class TestPeerAwareEviction:
    def test_priority_prefers_peer_cold_victim_on_ties(self):
        """Two equally-old entries: the peer-hot one must outlive the
        peer-cold one when the policy is peer-aware (and must NOT without
        the flag — slot order decides)."""
        d, p = 8, 2
        rng = np.random.default_rng(8)
        keys = _unit(rng, 3, d)
        for peer_aware, survivor in ((True, 0), (False, 1)):
            cache = SemanticCache(
                capacity=2, key_dim=d, payload_dim=p, threshold=0.9,
                policy=EvictionPolicy("lru", peer_aware=peer_aware))
            state = cache.init()
            state = cache.insert(state, jnp.asarray(keys[:2]),
                                 jnp.zeros((2, p), jnp.float32))
            # slot 0 served a peer (same logical age: touch only bumps
            # peer_served here, last_used already equals the insert clock)
            state = dataclasses.replace(
                state,
                peer_served=state.peer_served.at[0].add(3),
            )
            state = cache.insert(state, jnp.asarray(keys[2:]),
                                 jnp.zeros((1, p), jnp.float32))
            _, res = cache.lookup(state, jnp.asarray(keys))
            hit = np.asarray(res.hit)
            assert hit[survivor] and hit[2], (peer_aware, hit)
            assert not hit[1 - survivor], (peer_aware, hit)

    def test_cluster_peer_hot_entry_survives_eviction(self):
        """Through the real serve path: node 0 holds A and B from one
        insert batch (equal FIFO age); A keeps getting served to node 1
        (touch -> peer_served).  When node 0 must evict, B goes, A stays."""
        rng = np.random.default_rng(9)
        d, p = 32, 4
        pool = _unit(rng, 3, d)
        cl = CooperativeEdgeCluster(ClusterConfig(
            num_nodes=2, node_capacity=2, key_dim=d, payload_dim=p,
            threshold=0.9, admission="never",
            policy=EvictionPolicy("fifo", peer_aware=True)))
        cl.insert(0, jnp.asarray(pool[:2]), jnp.zeros((2, p), jnp.float32))
        for _ in range(2):                       # A = pool[0] is cluster-hot
            assert cl.lookup(1, jnp.asarray(pool[:1])).tier[0] == 1
        cl.insert(0, jnp.asarray(pool[2:]), jnp.zeros((1, p), jnp.float32))
        res = cl.lookup(0, jnp.asarray(pool))
        assert bool(res.hit[0]) and bool(res.hit[2])     # A + newcomer live
        assert not res.hit[1]                            # B evicted


# ---------------------------------------------------------------------------
# engine integration + dispatch bound, and the benchmark acceptance
# ---------------------------------------------------------------------------


def test_serving_engine_remote_tier(tiny_model, nprng):
    from repro.core.coic import CoICConfig
    from repro.serving.engine import ServingConfig, ServingEngine

    model, params = tiny_model
    cfg = ServingConfig(max_batch=4, max_len=64, max_new_tokens=4,
                        coic=CoICConfig(capacity=16, threshold=0.98,
                                        descriptor="sketch", num_nodes=2,
                                        num_clusters=2, digest_interval=1,
                                        admission="always"))
    eng = ServingEngine(model, params, cfg)
    prompt = nprng.integers(0, model.cfg.vocab_size, size=(16,)).astype(np.int32)

    eng.submit(prompt, node_id=0, cluster_id=0)
    eng.run_until_drained()
    assert eng.results[-1].source == "cloud"
    eng.submit(prompt, node_id=1, cluster_id=1)        # other metro
    eng.run_until_drained()
    assert eng.results[-1].source == "remote"
    assert eng.results[-1].decode_steps == 0           # served from cache
    assert eng.results[-1].breakdown.remote_net_ms > 0.0
    assert eng.results[-1].breakdown.cloud_net_ms == 0.0
    eng.submit(prompt, node_id=1, cluster_id=1)        # admitted locally
    eng.run_until_drained()
    assert eng.results[-1].source == "edge"
    np.testing.assert_array_equal(eng.results[0].tokens, eng.results[1].tokens)
    assert eng.stats()["remote_hits"] == 1


def test_engine_ladder_grows_at_most_two_dispatches(tiny_model, nprng):
    """Dispatch-counter acceptance: one engine step over requests from
    EVERY (cluster, node) runs 1 descriptor dispatch + 1 engine lookup,
    and the federation ladder under it stays at <= 4 device dispatches
    (2 intra-cluster + digest probe + confirm) regardless of K."""
    from repro.core.coic import CoICConfig
    from repro.serving.engine import ServingConfig, ServingEngine

    model, params = tiny_model
    for K in (2, 4):
        eng = ServingEngine(model, params, ServingConfig(
            max_batch=8, max_len=32, max_new_tokens=4,
            coic=CoICConfig(capacity=16, threshold=0.98,
                            descriptor="sketch", num_nodes=2,
                            num_clusters=K, digest_interval=1)))
        for k in range(K):
            for n in range(2):
                for _ in range(3):
                    eng.submit(nprng.integers(
                        0, model.cfg.vocab_size, size=(12,)).astype(np.int32),
                        node_id=n, cluster_id=k)
        eng.step()
        assert eng.dispatches["descriptor"] == 1
        assert eng.dispatches["lookup"] == 1
        assert not eng.pending
        assert eng.sem_fed.last_ladder_dispatches <= 4, (
            K, eng.sem_fed.last_ladder_dispatches)


def test_benchmark_federated_strictly_beats_isolated():
    """The acceptance scenario: at mobility > 0 on the roaming workload the
    federated tier's hit rate strictly exceeds isolated clusters, latency
    improves, and the ladder bound holds."""
    from benchmarks.federated_hit_rate import run

    rows = run(steps=12, users_per_node=4, pool=64, node_capacity=16,
               mobilities=(0.3,))
    parsed = {}
    for name, _, derived in rows:
        parsed[name] = dict(kv.split("=", 1) for kv in derived.split(";")
                            if "=" in kv)
    iso = parsed["fed_isolated_m0.3"]
    fed = parsed["fed_federated_m0.3"]
    assert float(fed["hit_rate"]) > float(iso["hit_rate"]), (iso, fed)
    assert float(fed["mean_latency_ms"]) < float(iso["mean_latency_ms"])
    assert int(fed["remote"]) > 0
    assert "digest_false_hit" in fed
    assert int(parsed["fed_ladder_dispatches"]["max"]) <= 4


def test_roaming_workload_mobility_zero_stays_home():
    wl = RoamingWorkload(num_clusters=3, nodes_per_cluster=2,
                         users_per_node=4, pool_size=32, dim=16,
                         mobility=0.0, seed=0)
    for _ in wl.stream(3, seed=1):
        pass
    assert (wl.current == wl.home).all()

    wl2 = RoamingWorkload(num_clusters=3, nodes_per_cluster=2,
                          users_per_node=4, pool_size=32, dim=16,
                          mobility=0.5, seed=0)
    n = 0
    for round_ in wl2.stream(3, seed=1):
        n += sum(len(ids) for _, _, ids, _ in round_)
    assert n == 3 * 3 * 2 * 4                    # every user, every round
    assert (wl2.current != wl2.home).any()
