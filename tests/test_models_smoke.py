"""Per-arch smoke tests (deliverable f): reduced same-family config, one
forward/train step on CPU, asserting output shapes + no NaNs."""
import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models import build_model


def _batch(cfg, rng, B=2, S=32):
    if cfg.family == "encdec":
        return {"enc_embeds": np.asarray(jax.random.normal(rng, (B, S, cfg.d_model)),
                                         np.float32),
                "dec_tokens": np.asarray(jax.random.randint(rng, (B, 16), 0,
                                                            cfg.vocab_size), np.int32)}
    batch = {"tokens": np.asarray(jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
                                  np.int32)}
    if cfg.num_image_patches:
        batch["image_embeds"] = np.asarray(
            jax.random.normal(rng, (B, cfg.num_image_patches, cfg.d_model)),
            np.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(rng)
    batch = _batch(cfg, rng)
    if cfg.family == "encdec":
        logits = model.forward(params, batch)
        B, Sd = batch["dec_tokens"].shape
        assert logits.shape == (B, Sd, cfg.vocab_size)
    else:
        logits = model.forward(params, batch["tokens"],
                               image_embeds=batch.get("image_embeds"))
        B, S = batch["tokens"].shape
        total = S + cfg.num_image_patches
        assert logits.shape == (B, total, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_reduces_nothing_nan(arch, rng):
    from repro.train.trainer import TrainerConfig, init_train_state, make_train_step

    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    tcfg = TrainerConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    state = init_train_state(model, rng, tcfg)
    step = jax.jit(make_train_step(model, tcfg))
    batch = _batch(cfg, rng)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state.step) == 1
    for k, v in state.params.items():
        assert np.all(np.isfinite(np.asarray(v, np.float32))), k


@pytest.mark.parametrize("arch", ["llama32_1b", "deepseek_v2_lite_16b",
                                  "jamba_v01_52b", "mamba2_2p7b"])
def test_scan_vs_unrolled_equivalence(arch, rng):
    """scan-over-layers and the unrolled python loop compute the same fn."""
    import dataclasses

    # fp32: under bf16, MoE router top-k near-ties can flip expert choice
    # between the two schedules — numerics, not a scan bug
    cfg = dataclasses.replace(reduced_config(get_config(arch)), dtype="float32")
    cfg_s = dataclasses.replace(cfg, scan_layers=True,
                                num_layers=8 if cfg.family == "hybrid" else 4)
    cfg_u = dataclasses.replace(cfg_s, scan_layers=False)
    m_s, m_u = build_model(cfg_s), build_model(cfg_u)
    params = m_s.init(rng)
    toks = np.asarray(jax.random.randint(rng, (2, 24), 0, cfg.vocab_size), np.int32)
    np.testing.assert_allclose(
        np.asarray(m_s.forward(params, toks), np.float32),
        np.asarray(m_u.forward(params, toks), np.float32), rtol=2e-2, atol=2e-2)
