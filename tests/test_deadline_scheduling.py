"""Frame-deadline-aware scheduling: EDF/FIFO equivalence properties,
expired-deadline handling, chunked-prefill bit-exactness, and the per-step
ladder dispatch bound under chunking."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.coic import CoICConfig
from repro.core.router import DeadlineStats, LatencyBreakdown
from repro.data.workload import FramePacedWorkload
from repro.serving.engine import ServingConfig, ServingEngine


@pytest.fixture(scope="module")
def fp32_model():
    # fp32: bf16 near-ties can flip argmax between bucketed batch widths
    # (different reduction order), which is numerics, not scheduling
    from repro.configs import get_config
    from repro.models import build_model

    cfg = dataclasses.replace(get_config("coic-paper"), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(vocab, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=(L,)).astype(np.int32) for L in lens]


def _serve(model, params, prompts, deadlines=None, priorities=None,
           policy="edf", max_batch=2, max_new=4, chunk=0, pacing=1,
           step_ms=0.0, coic=None):
    eng = ServingEngine(model, params, ServingConfig(
        max_batch=max_batch, max_len=96, max_new_tokens=max_new,
        queue_policy=policy, prefill_chunk=chunk, chunk_pacing=pacing,
        step_ms=step_ms, coic=coic))
    for i, p in enumerate(prompts):
        eng.submit(p,
                   priority=(priorities[i] if priorities else 0),
                   deadline_ms=(deadlines[i] if deadlines else None))
    eng.run_until_drained()
    return eng


def _result_map(eng):
    return {r.req_id: (r.source, tuple(int(t) for t in r.tokens),
                       r.finish_step) for r in eng.results}


# ---------------------------------------------------------------------------
# EDF <-> FIFO equivalence properties
# ---------------------------------------------------------------------------


def test_edf_without_deadlines_equals_fifo(fp32_model):
    """A batch with NO deadlines must drain in exactly FIFO order under
    EDF — same sources, tokens, and per-request finish steps."""
    cfg, model, params = fp32_model
    prompts = _prompts(cfg.vocab_size, [16, 24, 12, 20, 16])
    e_edf = _serve(model, params, prompts, policy="edf")
    e_fifo = _serve(model, params, prompts, policy="fifo")
    assert _result_map(e_edf) == _result_map(e_fifo)


def test_edf_all_equal_deadlines_equals_fifo(fp32_model):
    """ALL requests bearing the same deadline ties back to FIFO order
    (ties broken by submission order)."""
    cfg, model, params = fp32_model
    prompts = _prompts(cfg.vocab_size, [16, 24, 12, 20])
    dls = [500.0] * len(prompts)
    e_edf = _serve(model, params, prompts, deadlines=dls, policy="edf")
    e_fifo = _serve(model, params, prompts, deadlines=dls, policy="fifo")
    assert _result_map(e_edf) == _result_map(e_fifo)


def test_deadline_request_jumps_bulk_backlog(fp32_model):
    """With one slot, a frame request submitted AFTER three bulk requests
    is admitted first under EDF and last under FIFO."""
    cfg, model, params = fp32_model
    prompts = _prompts(cfg.vocab_size, [24, 24, 24, 12])
    dls = [None, None, None, 40.0]
    e_edf = _serve(model, params, prompts, deadlines=dls, policy="edf",
                   max_batch=1, step_ms=2.0)
    e_fifo = _serve(model, params, prompts, deadlines=dls, policy="fifo",
                    max_batch=1, step_ms=2.0)
    edf, fifo = _result_map(e_edf), _result_map(e_fifo)
    # the frame (rid 3) finishes before every bulk request under EDF...
    assert edf[3][2] < min(edf[r][2] for r in (0, 1, 2))
    # ...and after every bulk request under FIFO
    assert fifo[3][2] > max(fifo[r][2] for r in (0, 1, 2))
    # scheduling must never change the tokens anyone decodes
    for rid in edf:
        assert edf[rid][1] == fifo[rid][1]


def test_priority_breaks_ties_within_class(fp32_model):
    """Equal deadlines: higher priority admits first; bulk (no deadline)
    orders by priority too."""
    cfg, model, params = fp32_model
    prompts = _prompts(cfg.vocab_size, [16, 16, 16])
    eng = _serve(model, params, prompts, deadlines=[100.0, 100.0, None],
                 priorities=[0, 5, 0], policy="edf", max_batch=1)
    res = _result_map(eng)
    assert res[1][2] <= res[0][2] <= res[2][2]


# ---------------------------------------------------------------------------
# expired deadlines
# ---------------------------------------------------------------------------


def test_expired_deadline_still_served_and_counted(fp32_model):
    """A request whose budget is already blown at submit time is served
    (never dropped) and counted as a per-tier deadline miss."""
    cfg, model, params = fp32_model
    prompts = _prompts(cfg.vocab_size, [16])
    eng = _serve(model, params, prompts, deadlines=[0.0], step_ms=2.0)
    assert len(eng.results) == 1
    r = eng.results[0]
    assert r.deadline_miss and r.deadline_ms == 0.0
    assert len(r.tokens) == 4                       # fully served
    assert eng.deadline.missed == {"cloud": 1}
    assert eng.deadline.miss_rate() == 1.0


def test_deadline_stats_ignores_bulk():
    st = DeadlineStats()
    assert st.observe("edge", 1e9, None) is False
    assert st.observed == 0
    assert st.observe("edge", 5.0, 10.0) is False
    assert st.observe("cloud", 20.0, 10.0) is True
    assert st.met == {"edge": 1} and st.missed == {"cloud": 1}
    assert st.miss_rate() == 0.5


def test_coic_engine_deadline_accounting(tiny_model):
    """CoICEngine.process_batch threads per-request budgets onto the CoIC
    breakdowns and accumulates per-tier met/missed counts."""
    from repro.core.coic import CoICEngine, recognition_cloud_fn

    model, params = tiny_model
    cloud = recognition_cloud_fn(model, params, num_classes=8)
    eng = CoICEngine(model, params,
                     CoICConfig(capacity=16, threshold=0.98, payload_dim=8,
                                descriptor="sketch", descriptor_dim=64),
                     cloud_fn=cloud)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, model.cfg.vocab_size, size=(2, 16)).astype(np.int32)
    res = eng.process_batch(toks, deadline_ms=[1e9, None])
    assert res[0].coic.deadline_ms == 1e9
    assert res[0].coic.deadline_miss is False
    assert res[1].coic.deadline_ms is None        # bulk: not observed
    assert res[1].coic.deadline_miss is None
    st = eng.stats()["deadline"]
    assert st["observed"] == 1 and st["met"] == {"cloud": 1}
    # a scalar budget applies to the whole batch; an impossible one misses
    eng.process_batch(toks, deadline_ms=1e-6)
    st = eng.stats()["deadline"]
    assert sum(st["missed"].values()) == 2


def test_latency_breakdown_deadline_miss():
    lat = LatencyBreakdown(lookup_ms=5.0)
    assert lat.deadline_miss is None                # bulk: no deadline
    lat.deadline_ms = 10.0
    assert lat.deadline_miss is False
    lat.deadline_ms = 1.0
    assert lat.deadline_miss is True


# ---------------------------------------------------------------------------
# chunked-prefill admission
# ---------------------------------------------------------------------------


def test_chunked_prefill_bit_identical_tokens(fp32_model):
    """A long prompt admitted chunk-by-chunk must decode exactly the
    one-shot prefill's tokens (the test_layer_reuse equivalence at engine
    scope), while short prompts interleave with the trickle."""
    cfg, model, params = fp32_model
    prompts = _prompts(cfg.vocab_size, [50, 12, 12, 12])
    e_one = _serve(model, params, prompts, chunk=0, max_batch=2, max_new=6)
    e_chk = _serve(model, params, prompts, chunk=8, max_batch=2, max_new=6)
    one, chk = _result_map(e_one), _result_map(e_chk)
    for rid in one:
        assert one[rid][1] == chk[rid][1], rid
    # the long prompt really took the chunk path: ceil(50/8) dispatches
    # for it (plus 2 per 12-token prompt, 12 > 8)
    assert e_chk.dispatches["prefill_chunk"] >= 7
    assert e_one.dispatches["prefill_chunk"] == 0


def test_chunked_long_prompt_does_not_stall_shorts(fp32_model):
    """One huge prompt + three shorts, two slots: the shorts must all
    retire before the chunked long prompt (it trickles while they run)."""
    cfg, model, params = fp32_model
    prompts = _prompts(cfg.vocab_size, [64, 8, 8, 8])
    eng = _serve(model, params, prompts, chunk=8, max_batch=2, max_new=4)
    res = _result_map(eng)
    assert max(res[r][2] for r in (1, 2, 3)) < res[0][2]


def test_chunk_pacing_never_changes_tokens(fp32_model):
    """Priority-aware chunk pacing (multiple chunk dispatches per step
    while slots sit idle) must decode exactly the fixed-trickle tokens —
    pacing changes WHEN prefill work happens, never its result — and the
    paced long prompt must finish no later."""
    cfg, model, params = fp32_model
    prompts = _prompts(cfg.vocab_size, [64, 12, 12])
    e_slow = _serve(model, params, prompts, chunk=8, pacing=1,
                    max_batch=4, max_new=6)
    e_fast = _serve(model, params, prompts, chunk=8, pacing=4,
                    max_batch=4, max_new=6)
    slow, fast = _result_map(e_slow), _result_map(e_fast)
    for rid in slow:
        assert slow[rid][1] == fast[rid][1], rid      # identical tokens
    assert fast[0][2] <= slow[0][2]                   # long prompt no later
    # the paced engine really advanced multiple chunks in one step: fewer
    # steps elapsed before the long prompt's slot activated
    assert e_fast.dispatches["prefill_chunk"] == \
        e_slow.dispatches["prefill_chunk"]            # same total chunk work


def test_chunk_pacing_defers_to_queued_admissions(fp32_model):
    """Pacing only spends IDLE capacity: with an admission backlog wider
    than the slot count, the paced engine behaves exactly like the fixed
    trickle (no queued request waits on an extra chunk dispatch)."""
    cfg, model, params = fp32_model
    prompts = _prompts(cfg.vocab_size, [64, 12, 12, 12, 12, 12])
    e_slow = _serve(model, params, prompts, chunk=8, pacing=1,
                    max_batch=2, max_new=4)
    e_fast = _serve(model, params, prompts, chunk=8, pacing=4,
                    max_batch=2, max_new=4)
    assert _result_map(e_slow) == _result_map(e_fast)


def test_ladder_bound_under_edf_and_chunking(fp32_model):
    """Dispatch-counter acceptance: EDF + chunked prefill + a federated
    CoIC front still run at most ONE descriptor + ONE grouped lookup per
    engine step, and the federation's internal ladder stays <= 4."""
    cfg, model, params = fp32_model
    wl = FramePacedWorkload(num_clusters=2, nodes_per_cluster=2,
                            frame_users_per_node=2, bulk_users_per_node=2,
                            bulk_rate=0.7, pool_size=24, seed=3)
    frame_p, bulk_p = wl.token_prompts(cfg.vocab_size, 12, 40)
    eng = ServingEngine(model, params, ServingConfig(
        max_batch=4, max_len=64, max_new_tokens=4, queue_policy="edf",
        prefill_chunk=16, step_ms=wl.step_ms,
        coic=CoICConfig(capacity=16, threshold=0.98, descriptor="sketch",
                        descriptor_dim=64, num_nodes=2, num_clusters=2,
                        digest_size=8, digest_interval=2)))
    for round_ in wl.stream(10, seed=4):
        for fr in round_:
            eng.submit(bulk_p[fr.scene] if fr.bulk else frame_p[fr.scene],
                       node_id=fr.node, cluster_id=fr.cluster,
                       priority=fr.priority, deadline_ms=fr.deadline_ms)
        eng.step()
    eng.run_until_drained()
    assert eng.max_step_ladder <= 2                  # 1 desc + 1 lookup
    assert eng.sem_fed.stats()["max_ladder_dispatches"] <= 4
    assert eng.dispatches["prefill_chunk"] > 0       # chunking exercised
    assert eng.deadline.observed > 0                 # deadlines accounted


# ---------------------------------------------------------------------------
# frame-paced workload shape
# ---------------------------------------------------------------------------


def test_frame_paced_workload_rates_and_deadlines():
    wl = FramePacedWorkload(num_clusters=2, nodes_per_cluster=2,
                            frame_users_per_node=2, fps_choices=(50,),
                            bulk_users_per_node=1, bulk_rate=1.0,
                            step_ms=5.0, pool_size=16, seed=0)
    rounds = list(wl.stream(100, seed=1))
    frames = [r for rnd in rounds for r in rnd if not r.bulk]
    bulk = [r for rnd in rounds for r in rnd if r.bulk]
    # 8 frame users at 50 FPS over 100 x 5 ms = 0.5 s -> ~200 frames
    assert 190 <= len(frames) <= 210, len(frames)
    assert len(bulk) == 4 * 100                      # bulk_rate=1.0
    assert all(r.deadline_ms == 20.0 for r in frames)   # 1 frame @ 50 FPS
    assert all(r.deadline_ms is None and r.priority == 0 for r in bulk)
    assert {r.cluster for r in frames} <= {0, 1}
    assert {r.node for r in frames} <= {0, 1}


def test_frame_paced_workload_mobility_moves_users():
    wl = FramePacedWorkload(num_clusters=3, nodes_per_cluster=1,
                            frame_users_per_node=4, bulk_users_per_node=0,
                            mobility=1.0, seed=0)
    rng = np.random.default_rng(0)
    moved = wl.migrate(rng)
    assert moved == wl._n_users
    assert (wl.current != wl.home).all()
    wl0 = FramePacedWorkload(num_clusters=3, nodes_per_cluster=1,
                             frame_users_per_node=4, bulk_users_per_node=0,
                             mobility=0.0, seed=0)
    assert wl0.migrate(rng) == 0


# ---------------------------------------------------------------------------
# benchmark acceptance (slow tier)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_frame_deadline_benchmark_acceptance():
    """EDF strictly beats FIFO on p99 motion-to-photon latency AND
    deadline-miss rate at equal offered load, with the dispatch bound
    held under chunked prefill."""
    from benchmarks.frame_deadline import run_smoke

    rows = {name: derived for name, _, derived in run_smoke()}
    kv = dict(p.split("=", 1) for p in rows["frame_edf_vs_fifo"].split(";"))
    assert kv["ok"] == "True", rows["frame_edf_vs_fifo"]
    kv = dict(p.split("=", 1) for p in rows["frame_dispatch_bound"].split(";"))
    assert kv["ok"] == "True", rows["frame_dispatch_bound"]
