"""Checkpoint/restore: atomicity, retention, async, restore-with-resharding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32),
                       "b": jnp.asarray(rng.standard_normal(4), jnp.float32)},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    ckpt = Checkpointer(str(tmp_path), async_save=False)
    state = _state()
    ckpt.save(7, state)
    restored = ckpt.restore(7, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_wait(tmp_path):
    ckpt = Checkpointer(str(tmp_path), async_save=True)
    state = _state()
    ckpt.save(1, state)
    ckpt.wait()
    assert ckpt.latest_step() == 1
    restored = ckpt.restore(1, state)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_retention_keeps_newest(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2, async_save=False)
    state = _state()
    for s in (1, 2, 3, 4):
        ckpt.save(s, state)
    assert ckpt.steps() == [3, 4]


def test_no_tmp_dirs_left(tmp_path):
    ckpt = Checkpointer(str(tmp_path), async_save=False)
    ckpt.save(5, _state())
    assert not list(tmp_path.glob("*.tmp"))


def test_restore_with_new_sharding(tmp_path):
    """Restore placing leaves with explicit (new-mesh) shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    sh = NamedSharding(mesh, P())
    ckpt = Checkpointer(str(tmp_path), async_save=False)
    state = _state()
    ckpt.save(2, state)
    shardings = jax.tree.map(lambda _: sh, state)
    restored = ckpt.restore(2, state, shardings=shardings)
    assert restored["params"]["w"].sharding == sh
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_restore_missing_leaf_raises(tmp_path):
    ckpt = Checkpointer(str(tmp_path), async_save=False)
    state = _state()
    ckpt.save(3, state)
    bigger = dict(state)
    bigger["params"] = dict(state["params"], extra=jnp.zeros(3))
    with pytest.raises(KeyError):
        ckpt.restore(3, bigger)
