"""Unit suite for the membership control plane (core/membership.py):
heartbeat expiry, detection vs ground truth, idempotent double-kill,
deterministic re-election/routing, and the federation listener's
tombstone + re-elect wiring."""
import numpy as np
import pytest

from repro.core.cluster import ClusterConfig
from repro.core.federation import FederatedEdgeTier, FederationConfig
from repro.core.membership import (ClusterMembership, HeartbeatMonitor,
                                   MembershipEvent, SimulatedFailure)
from repro.core.policies import EvictionPolicy

K, N, D, CAP = 3, 2, 32, 8


def _mk_membership(**kw):
    kw.setdefault("timeout_s", 2.0)
    return ClusterMembership(K, N, **kw)


def _mk_fed(region_aware=False, threshold=0.8):
    policy = EvictionPolicy("lru", region_aware=region_aware)
    return FederatedEdgeTier(FederationConfig(
        num_clusters=K, digest_size=4, digest_interval=1,
        cluster=ClusterConfig(num_nodes=N, node_capacity=CAP, key_dim=D,
                              payload_dim=4, threshold=threshold,
                              policy=policy)))


def _unit(rng, n):
    x = rng.standard_normal((n, D)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


class TestHeartbeat:
    def test_expiry_on_logical_clock(self):
        mon = HeartbeatMonitor(["a", "b"], timeout_s=5.0)
        mon.beat("a", at=0.0)
        mon.beat("b", at=0.0)
        assert mon.dead(now=4.0) == []
        mon.beat("b", at=4.0)
        assert mon.dead(now=6.0) == ["a"]
        assert mon.alive(now=6.0) == ["b"]

    def test_silent_crash_detected_at_sweep_not_before(self):
        mb = _mk_membership()
        mb.kill_cluster(1, announce=False, now=100.0)
        # ground truth flips immediately; detection has not fired
        assert not mb.is_alive(1)
        assert mb.detected_alive[1]
        assert mb.events == []
        # before the timeout the sweep sees nothing... (the kill pinned the
        # last beat 2*timeout back, so any sweep detects it; beat at 100
        # for the survivors to keep them alive)
        mb.beat(0, at=100.0)
        mb.beat(2, at=100.0)
        assert mb.sweep(now=101.0) == [1]
        assert not mb.detected_alive[1]
        assert [e.kind for e in mb.events] == ["cluster_dead"]
        assert mb.stats()["heartbeat_expiries"] == 1

    def test_beating_cluster_never_expires(self):
        mb = _mk_membership()
        for t in range(10):
            for k in range(K):
                mb.beat(k, at=float(t))
            assert mb.sweep(now=float(t) + 0.5) == []
        assert all(mb.alive_clusters())

    def test_announced_kill_detects_immediately(self):
        mb = _mk_membership()
        mb.kill_cluster(2, announce=True)
        assert [e.kind for e in mb.events] == ["cluster_dead"]
        assert not mb.is_alive(2)
        # the later sweep does not re-detect (survivors keep beating)
        mb.beat(0, at=1e9)
        mb.beat(1, at=1e9)
        assert mb.sweep(now=1e9) == []


class TestIdempotence:
    def test_double_kill_is_noop(self):
        mb = _mk_membership()
        assert mb.kill_cluster(0) is True
        assert mb.kill_cluster(0) is False
        assert len([e for e in mb.events if e.kind == "cluster_dead"]) == 1
        assert mb.stats()["cluster_kills"] == 1

    def test_double_revive_is_noop(self):
        mb = _mk_membership()
        mb.kill_cluster(0)
        assert mb.revive_cluster(0) is True
        assert mb.revive_cluster(0) is False
        assert mb.stats()["cluster_revives"] == 1

    def test_node_double_kill_and_attrition_death(self):
        mb = _mk_membership()
        assert mb.kill_node(1, 0) is True
        assert mb.kill_node(1, 0) is False
        assert mb.is_alive(1)                      # one node still up
        mb.kill_node(1, 1)
        # last node down takes the cluster with it
        assert not mb.is_alive(1)
        kinds = [e.kind for e in mb.events]
        assert kinds.count("cluster_dead") == 1
        # first node back re-animates the cluster
        mb.revive_node(1, 0)
        assert mb.is_alive(1)
        assert mb.events[-1].kind == "cluster_alive"


class TestRouting:
    def test_route_is_deterministic_upward_scan(self):
        mb = _mk_membership()
        mb.kill_cluster(1)
        # every request targeting cluster 1 remaps to cluster 2 (upward)
        for _ in range(3):
            assert mb.route(1, 0) == (2, 0)
        mb.kill_cluster(2)
        assert mb.route(1, 0) == (0, 0)
        assert mb.route(2, 1) == (0, 1)

    def test_route_dead_node_within_cluster(self):
        mb = _mk_membership()
        mb.kill_node(0, 0)
        assert mb.route(0, 0) == (0, 1)
        assert mb.route(0, 1) == (0, 1)            # alive target untouched

    def test_route_all_dead_returns_unchanged(self):
        mb = _mk_membership()
        for k in range(K):
            mb.kill_cluster(k)
        assert mb.route(1, 1) == (1, 1)

    def test_reelection_determinism_under_fixed_seed(self):
        # two independent runs with the same kill sequence route the same
        # request stream identically
        def run():
            mb = _mk_membership()
            rng = np.random.default_rng(7)
            out = []
            for step in range(20):
                if step % 5 == 4:
                    k = int(rng.integers(K))
                    if mb.is_alive(k) and mb.alive_clusters().sum() > 1:
                        mb.kill_cluster(k)
                    elif not mb.cluster_alive[k]:
                        mb.revive_cluster(k)
                out.append(mb.route(int(rng.integers(K)),
                                    int(rng.integers(N))))
            return out

        assert run() == run()


class TestFederationWiring:
    def test_detected_death_tombstones_and_wipes(self):
        fed = _mk_fed()
        mb = _mk_membership()
        fed.attach_membership(mb)
        rng = np.random.default_rng(0)
        keys = _unit(rng, 4)
        for k in range(K):
            fed.insert(k, 0, keys, np.zeros((4, 4), np.float32))
        fed.refresh_digests()
        assert fed.board.valid[1].any()
        mb.kill_cluster(1)
        # digest rows tombstoned, shards wiped, publisher reset
        assert not fed.board.valid[1].any()
        assert fed.board.tombstones == 1
        assert not any(np.asarray(s.valid).any()
                       for s in fed.clusters[1].states)
        assert not fed.publishers[1]._valid.any()
        # survivors untouched
        assert fed.board.valid[0].any() and fed.board.valid[2].any()

    def test_remote_dead_counted_never_served(self):
        fed = _mk_fed()
        mb = _mk_membership()
        fed.attach_membership(mb)
        rng = np.random.default_rng(1)
        keys = _unit(rng, 2)
        fed.insert(1, 0, keys, np.ones((2, 4), np.float32))
        fed.refresh_digests()
        # cluster 1 dies SILENTLY: the board still advertises it
        mb.kill_cluster(1, announce=False, now=0.0)
        assert fed.board.valid[1].any()
        res = fed.lookup(0, 0, keys)               # would remote-hit on 1
        assert not res.hit.any()                   # refused, fell through
        assert fed.remote_dead == 2
        assert fed.tier_counts["remote_dead"] == 2
        assert fed.stats()["membership"]["alive_clusters"] == K - 1

    def test_revive_is_cold_and_board_cleared(self):
        fed = _mk_fed()
        mb = _mk_membership()
        fed.attach_membership(mb)
        rng = np.random.default_rng(2)
        keys = _unit(rng, 2)
        fed.insert(0, 0, keys, np.ones((2, 4), np.float32))
        fed.refresh_digests()
        # undetected crash + revive: the stale pre-crash advert must clear
        mb.kill_cluster(0, announce=False, now=0.0)
        mb.revive_cluster(0, now=0.0)
        assert not fed.board.valid[0].any()
        assert not any(np.asarray(s.valid).any()
                       for s in fed.clusters[0].states)
        res = fed.lookup(1, 0, keys)
        assert not res.hit.any()                   # nothing phantom-served

    def test_node_kill_loses_entries_not_phantom(self):
        fed = _mk_fed()
        mb = _mk_membership()
        fed.attach_membership(mb)
        rng = np.random.default_rng(3)
        keys = _unit(rng, 2)
        fed.insert(0, 1, keys, np.ones((2, 4), np.float32))
        assert fed.lookup(0, 1, keys).hit.all()
        mb.kill_node(0, 1)
        res = fed.lookup(0, 0, keys)               # peer probe to dead shard
        assert not res.hit.any()

    def test_region_pin_reelected_on_cluster_death(self):
        fed = _mk_fed(region_aware=True)
        mb = _mk_membership()
        fed.attach_membership(mb)
        rng = np.random.default_rng(4)
        key = _unit(rng, 1)
        # the same entry lives at clusters 0 and 1; both are region-hot
        for k in (0, 1):
            fed.insert(k, 0, key, np.ones((1, 4), np.float32))
            st = fed.clusters[k].states[0]
            import dataclasses as dc
            import jax.numpy as jnp
            fed.clusters[k].states[0] = dc.replace(
                st, peer_served=jnp.asarray(
                    np.asarray(st.peer_served) + 2))
        fed.refresh_digests()
        # lowest-id hot holder (cluster 0) pins; cluster 1 defers
        assert np.asarray(fed.clusters[0].states[0].region_pin).any()
        assert not np.asarray(fed.clusters[1].states[0].region_pin).any()
        mb.kill_cluster(0)
        # re-election: the next-hottest advertiser (cluster 1) now pins
        assert np.asarray(fed.clusters[1].states[0].region_pin).any()

    def test_simulated_failure_reexport(self):
        # train/elastic.py keeps its legacy import surface
        from repro.train.elastic import (HeartbeatMonitor as HM,
                                         SimulatedFailure as SF)
        assert HM is HeartbeatMonitor and SF is SimulatedFailure
        err = SimulatedFailure(3)
        assert err.surviving_data_shards == 3

    def test_events_carry_step_and_metrics(self):
        mb = _mk_membership()
        mb.step = 7
        mb.kill_node(0, 1)
        ev = mb.events[0]
        assert isinstance(ev, MembershipEvent)
        assert (ev.kind, ev.cluster, ev.node, ev.step) == ("node_dead", 0,
                                                           1, 7)
        s = mb.stats()
        assert s["node_kills"] == 1 and s["alive_nodes"] == K * N - 1
        assert mb.metrics.counter("membership/node_kills").value == 1


class TestRegionPinSequence:
    """Seeded deterministic twin of test_federation_properties.py::
    test_region_pin_released_on_eviction_and_death (the container may not
    ship hypothesis) — pin-election invariants under a seeded interleaving
    of holder deaths, cold revives, and capacity evictions."""

    TAU, CAP, D = 0.8, 4, 24

    def _mk(self):
        policy = EvictionPolicy("lru", region_aware=True)
        fed = FederatedEdgeTier(FederationConfig(
            num_clusters=K, digest_size=self.CAP, digest_interval=1,
            cluster=ClusterConfig(
                num_nodes=1, node_capacity=self.CAP, key_dim=self.D,
                payload_dim=3, threshold=self.TAU, policy=policy,
                admission="never")))
        mb = ClusterMembership(K, 1, timeout_s=2.0)
        fed.attach_membership(mb)
        return fed, mb

    def _check(self, fed, mb, shared):
        import dataclasses  # noqa: F401  (kept for symmetry with _hot)
        holders, pinners = [], []
        for k, cl in enumerate(fed.clusters):
            s = cl.states[0]
            valid = np.asarray(s.valid)
            pin = np.asarray(s.region_pin)
            assert not (pin & ~valid).any(), k        # pins on valid rows only
            if not mb.is_alive(k):
                assert not pin.any(), k               # dead holds no pins
                continue
            match = valid & ((np.asarray(s.keys) @ shared) >= self.TAU)
            if (match & (np.asarray(s.peer_served) >= 1)).any():
                holders.append(k)
            if (pin & match).any():
                pinners.append(k)
        # deterministic election: exactly the lowest-id alive hot holder
        assert pinners == (holders[:1] if holders else []), \
            (holders, pinners)

    def _hot(self, fed, k, shared):
        import dataclasses as dc
        import jax.numpy as jnp
        fed.insert(k, 0, jnp.asarray(shared[None, :]),
                   jnp.ones((1, 3), jnp.float32))
        s = fed.clusters[k].states[0]
        fed.clusters[k].states[0] = dc.replace(
            s, peer_served=jnp.asarray(np.asarray(s.peer_served) + 2))

    @pytest.mark.parametrize("seed", range(3))
    def test_pin_released_on_eviction_and_death(self, seed):
        import jax.numpy as jnp
        rng = np.random.default_rng(seed)
        fed, mb = self._mk()
        pool = rng.standard_normal((12, self.D)).astype(np.float32)
        pool /= np.linalg.norm(pool, axis=1, keepdims=True)
        shared = pool[0]
        for k in range(K):
            self._hot(fed, k, shared)
        fed.refresh_digests()
        self._check(fed, mb, shared)
        for _ in range(8):
            op = rng.choice(["kill", "revive", "evict", "noop"])
            if op == "kill":
                alive = [k for k in range(K) if mb.is_alive(k)]
                if len(alive) > 1:
                    mb.kill_cluster(alive[0])         # takes the pin holder
            elif op == "revive":
                dead = [k for k in range(K) if not mb.cluster_alive[k]]
                if dead:
                    mb.revive_cluster(dead[0])        # rejoins COLD
            elif op == "evict":
                alive = [k for k in range(K) if mb.is_alive(k)]
                k = alive[int(rng.integers(len(alive)))]
                fed.insert(k, 0, jnp.asarray(pool[1:1 + self.CAP]),
                           jnp.ones((self.CAP, 3), jnp.float32))
            fed.refresh_digests()
            self._check(fed, mb, shared)
