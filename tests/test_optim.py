"""Optimizer + schedule + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import AdamW, AdamWConfig
from repro.optim.grad_compress import (ef_int8_compress, ef_int8_decompress,
                                       topk_compress)
from repro.optim.schedule import cosine_with_warmup


def test_adamw_minimizes_quadratic():
    opt = AdamW(AdamWConfig(weight_decay=0.0), lambda s: jnp.float32(0.1))
    params = {"w": jnp.asarray([3.0, -2.0], jnp.float32)}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}             # d/dw ||w||^2
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.linalg.norm(params["w"])) < 1e-2


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(grad_clip_norm=1.0, weight_decay=0.0)
    opt = AdamW(cfg, lambda s: jnp.float32(1.0))
    params = {"w": jnp.zeros((4,), jnp.float32)}
    state = opt.init(params)
    huge = {"w": jnp.full((4,), 1e9, jnp.float32)}
    _, _, metrics = opt.update(huge, state, params)
    assert float(metrics["grad_norm"]) > 1e8       # reported pre-clip


def test_weight_decay_skips_vectors():
    cfg = AdamWConfig(weight_decay=0.5)
    opt = AdamW(cfg, lambda s: jnp.float32(0.1))
    params = {"mat": jnp.ones((2, 2)), "vec": jnp.ones((2,))}
    state = opt.init(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = opt.update(zero_g, state, params)
    assert float(new["mat"][0, 0]) < 1.0           # decayed
    assert float(new["vec"][0]) == 1.0             # not decayed


def test_cosine_schedule_shape():
    sched = cosine_with_warmup(1.0, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0, abs=0.02)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, abs=0.02)
    mid = float(sched(jnp.asarray(55)))
    assert 0.1 < mid < 1.0


def test_ef_int8_roundtrip_small_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    err = jnp.zeros_like(g)
    q, scale, new_err = ef_int8_compress(g, err)
    deq = ef_int8_decompress(q, scale)
    rel = float(jnp.linalg.norm(deq - g) / jnp.linalg.norm(g))
    assert rel < 0.02                              # int8 quantization error
    np.testing.assert_allclose(np.asarray(deq + new_err), np.asarray(g),
                               rtol=1e-5, atol=1e-6)  # error feedback exact


def test_error_feedback_converges():
    """Accumulated compressed sum approaches the true sum (unbiased-ish)."""
    rng = np.random.default_rng(1)
    true_acc = np.zeros(100)
    comp_acc = np.zeros(100)
    err = jnp.zeros(100, jnp.float32)
    for _ in range(50):
        g = rng.standard_normal(100).astype(np.float32)
        true_acc += g
        q, scale, err = ef_int8_compress(jnp.asarray(g), err)
        comp_acc += np.asarray(ef_int8_decompress(q, scale))
    # residual error is bounded by one step's quantization error
    assert np.linalg.norm(true_acc - comp_acc) < np.linalg.norm(true_acc) * 0.05


def test_topk_keeps_largest():
    g = jnp.asarray(np.arange(100, dtype=np.float32) - 50)
    kept, err = topk_compress(g, jnp.zeros_like(g), k_ratio=0.1)
    nz = np.nonzero(np.asarray(kept))[0]
    assert len(nz) <= 11
    mags = np.abs(np.asarray(g)[nz])
    assert mags.min() >= np.sort(np.abs(np.asarray(g)))[-11]
    np.testing.assert_allclose(np.asarray(kept + err), np.asarray(g), rtol=1e-6)
