"""Paged KV cache: block tables, prefix sharing, tail-chunk compile reuse.

Covers the three contracts of the paged subsystem:

  * tail-chunk retrace fix — every dense chunk dispatch is the static
    (1, prefill_chunk) shape with the true width passed as data, so ONE
    ``prefill_chunk`` compile serves every remainder length, and chunked
    tokens stay bit-identical to one-shot prefill
  * paged + prefix-shared serving is bit-identical to the dense slotted
    path over random shared-prefix batches (mapped pages hold exactly the
    bytes prefill would have written), with refcounts draining to zero
    once the engine drains — property-tested via hypothesis when
    installed, with a seeded fallback that always runs
  * host-side bookkeeping units — copy-on-write remapping, scatter
    duplicate-slot rejection, free-list recycling of index entries
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.workload import SharedPrefixWorkload
from repro.models import build_model
from repro.serving.engine import ServingConfig, ServingEngine
from repro.serving.kv_cache import PagedKVCache, batch_cache_scatter


@pytest.fixture(scope="module")
def fp32_model():
    # fp32: bf16 near-ties can flip argmax between batch widths
    cfg = dataclasses.replace(get_config("coic-paper"), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _serve(model, params, prompts, **kw):
    eng = ServingEngine(model, params, ServingConfig(
        max_batch=kw.pop("max_batch", 4), max_len=kw.pop("max_len", 96),
        max_new_tokens=kw.pop("max_new", 6), **kw))
    rids = [eng.submit(p) for p in prompts]
    eng.run_until_drained()
    by = {r.req_id: r for r in eng.results}
    return eng, [by[rid].tokens for rid in rids]


def _shared_prefix_prompts(rng, vocab, n, prefix_lens=(33, 17),
                           suffix=(3, 20)):
    """Prompts drawn over a few shared heads + random private tails."""
    heads = [rng.integers(0, vocab, size=(L,)).astype(np.int32)
             for L in prefix_lens]
    out = []
    for i in range(n):
        sfx = rng.integers(0, vocab,
                           size=(int(rng.integers(*suffix)),)).astype(np.int32)
        out.append(np.concatenate([heads[i % len(heads)], sfx]))
    return out


# ---------------------------------------------------------------------------
# tail-chunk retrace fix
# ---------------------------------------------------------------------------


def test_tail_chunk_one_compile_across_remainders(fp32_model, nprng):
    """THE regression this PR pins: with prefill_chunk=16, prompts whose
    lengths leave >= 3 distinct tail remainders must share ONE
    ``prefill_chunk`` compile (the old code dispatched the raw remainder
    width, retracing per distinct length), and chunked tokens must equal
    the one-shot prefill path bit for bit."""
    cfg, model, params = fp32_model
    # remainders mod 16: 5, 3, 2, 0 — three distinct partial tails
    prompts = [nprng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in (21, 35, 50, 48)]
    eng_c, toks_c = _serve(model, params, prompts, prefill_chunk=16,
                           max_batch=2)
    assert eng_c.dispatches["prefill_chunk"] >= 8      # chunk path taken
    assert eng_c._chunk_fn._cache_size() == 1, \
        "tail chunks retraced: expected ONE compile for all remainders"
    eng_o, toks_o = _serve(model, params, prompts, max_batch=2)
    for c, o in zip(toks_c, toks_o):
        np.testing.assert_array_equal(c, o)


# ---------------------------------------------------------------------------
# paged == dense (seeded fallback property + hypothesis widening)
# ---------------------------------------------------------------------------


def _assert_paged_matches_dense(model, params, prompts):
    eng_d, toks_d = _serve(model, params, prompts)
    eng_p, toks_p = _serve(model, params, prompts, kv_page=16,
                           prefill_chunk=32)
    eng_n, toks_n = _serve(model, params, prompts, kv_page=16,
                           prefill_chunk=32, prefix_share=False)
    for d, p, n in zip(toks_d, toks_p, toks_n):
        np.testing.assert_array_equal(d, p)
        np.testing.assert_array_equal(d, n)
    # refcounts return to zero with the engine drained; every table slot
    # unmapped; sharing actually happened (same heads repeat)
    for eng in (eng_p, eng_n):
        assert (eng.kv.refcount == 0).all()
        assert (eng.kv.block_table == PagedKVCache.INVALID).all()
        assert eng.stats()["kv"]["pages_in_use"] == 0
    assert eng_p.prefill_tokens_shared > 0
    assert eng_n.prefill_tokens_shared == 0
    assert eng_p.prefill_tokens_computed < eng_n.prefill_tokens_computed
    return eng_p


def test_paged_prefix_sharing_bit_identical_seeded(fp32_model, nprng):
    """Seeded fallback (always runs): random shared-prefix batches decode
    the same tokens through the paged + prefix-shared path as through the
    dense slotted path, and sharing elides prefill compute."""
    cfg, model, params = fp32_model
    prompts = _shared_prefix_prompts(nprng, cfg.vocab_size, 7)
    eng = _assert_paged_matches_dense(model, params, prompts)
    assert eng.stats()["kv"]["pages_shared"] > 0


def test_paged_prefix_sharing_bit_identical_hypothesis(fp32_model):
    """Hypothesis widening of the same property: random suffix lengths,
    session mixes, and request counts."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    cfg, model, params = fp32_model

    @settings(max_examples=5, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1),
           st.integers(3, 8),
           st.lists(st.integers(2, 24), min_size=1, max_size=3))
    def prop(seed, n_req, prefix_extra):
        rng = np.random.default_rng(seed)
        prompts = _shared_prefix_prompts(
            rng, cfg.vocab_size, n_req,
            prefix_lens=tuple(16 + e for e in prefix_extra))
        _assert_paged_matches_dense(model, params, prompts)

    prop()


# ---------------------------------------------------------------------------
# in-place paged-attention kernel through the serving path
# ---------------------------------------------------------------------------


def test_paged_kernel_attn_bit_identical_tokens(fp32_model, nprng):
    """attn_impl="paged_interpret" (the fused in-place kernel, interpreted)
    decodes the same tokens as the gathered-view path AND the dense slotted
    path over a shared-prefix batch — prefill chunks, decode steps, idle
    rows, and shared pages all routed through the kernel."""
    cfg, model, params = fp32_model
    prompts = _shared_prefix_prompts(nprng, cfg.vocab_size, 6)
    eng_d, toks_d = _serve(model, params, prompts)
    eng_g, toks_g = _serve(model, params, prompts, kv_page=16,
                           prefill_chunk=32)
    eng_k, toks_k = _serve(model, params, prompts, kv_page=16,
                           prefill_chunk=32, attn_impl="paged_interpret")
    for d, g, k in zip(toks_d, toks_g, toks_k):
        np.testing.assert_array_equal(d, g)
        np.testing.assert_array_equal(d, k)
    assert eng_k.prefill_tokens_shared > 0     # kernel path saw shared pages


def test_paged_kernel_one_compile_across_occupancies(fp32_model, nprng):
    """The kernel grid is static over (B, heads, table width): page
    occupancy varies only through block-table/length DATA, so one decode
    compile must serve every mix — short rows, long rows, idle rows, and a
    second drained-and-refilled generation of requests."""
    cfg, model, params = fp32_model
    eng = ServingEngine(model, params, ServingConfig(
        max_batch=4, max_len=96, max_new_tokens=4, kv_page=16,
        prefill_chunk=32, attn_impl="paged_interpret"))
    for p in _shared_prefix_prompts(nprng, cfg.vocab_size, 5):
        eng.submit(p)
    eng.run_until_drained()
    assert eng._decode_paged._cache_size() == 1
    # refill with very different lengths/occupancies: decode never retraces
    for L in (3, 90, 41):
        eng.submit(nprng.integers(0, cfg.vocab_size, size=(L,)).astype(
            np.int32))
    eng.run_until_drained()
    assert eng._decode_paged._cache_size() == 1, \
        "decode retraced on a new page-occupancy mix"
    # the chunk dispatch batch width tracks the chunking-set size (a
    # pre-existing width-driven shape), so its trace count is bounded by
    # max_batch — page occupancy itself must add nothing on top
    assert eng._chunk_paged._cache_size() <= 4


def test_attn_impl_config_validation():
    """attn_impl is a paged-cache knob: reject it without kv_page, and
    reject unknown values."""
    with pytest.raises(AssertionError):
        ServingConfig(attn_impl="paged")
    with pytest.raises(AssertionError):
        ServingConfig(attn_impl="nope", kv_page=16, max_len=64)
    ServingConfig(attn_impl="paged", kv_page=16, max_len=64)   # fine


def test_paged_semantic_mode_serves(fp32_model, nprng):
    """The sketch-descriptor prefix index (prefix_mode="semantic") serves
    the exact-repeat workload too — exact entries win, the semantic path
    just widens; tokens still match dense."""
    cfg, model, params = fp32_model
    prompts = _shared_prefix_prompts(nprng, cfg.vocab_size, 5,
                                     prefix_lens=(33,))
    eng_d, toks_d = _serve(model, params, prompts)
    eng_s, toks_s = _serve(model, params, prompts, kv_page=16,
                           prefill_chunk=32, prefix_mode="semantic")
    for d, s in zip(toks_d, toks_s):
        np.testing.assert_array_equal(d, s)
    assert eng_s.stats()["kv"]["pages_shared"] > 0


# ---------------------------------------------------------------------------
# host-side bookkeeping units (no model needed)
# ---------------------------------------------------------------------------


def _mk_kv(**kw):
    return PagedKVCache(None, max_batch=2, max_len=64, page_size=16, **kw)


def test_admit_maps_shared_pages_and_register_publishes(nprng):
    kv = _mk_kv()
    prompt = nprng.integers(0, 99, size=(40,)).astype(np.int32)
    assert kv.admit(0, prompt) == 0                    # cold: nothing shared
    kv.register(0, prompt)                             # publish pages 0, 1
    shared = kv.admit(1, prompt)
    assert shared == 32                                # 2 full pages mapped
    assert (kv.block_table[1, :2] == kv.block_table[0, :2]).all()
    assert (kv.refcount[kv.block_table[0, :2]] == 2).all()
    # the sharing cap: the page holding the last token is never shared
    assert kv.block_table[1, 2] != kv.block_table[0, 2]
    kv.free_slot(0)
    kv.free_slot(1)
    assert (kv.refcount == 0).all()


def test_freed_pages_stay_probeable_until_recycled(nprng):
    kv = _mk_kv()
    prompt = nprng.integers(0, 99, size=(40,)).astype(np.int32)
    kv.admit(0, prompt)
    kv.register(0, prompt)
    kv.free_slot(0)                                    # refcounts to 0
    assert kv.admit(1, prompt) == 32                   # index still serves
    kv.free_slot(1)


def test_recycle_invalidates_index_entries(nprng):
    kv = PagedKVCache(None, max_batch=2, max_len=64, page_size=16,
                      num_pages=8)                     # exactly 2 slots' span
    p1 = nprng.integers(0, 99, size=(40,)).astype(np.int32)
    p2 = nprng.integers(100, 199, size=(40,)).astype(np.int32)
    kv.admit(0, p1)
    kv.register(0, p1)
    kv.free_slot(0)
    # churn through the whole pool: p1's pages are recycled for p2
    kv.admit(0, p2)
    kv.admit(1, p2)
    assert len(kv._exact) < 4                          # p1 entries died
    kv.free_slot(0)
    kv.free_slot(1)


def test_copy_on_write_remaps_writer():
    kv = _mk_kv()
    prompt = np.arange(40, dtype=np.int32)
    kv.admit(0, prompt)
    kv.register(0, prompt)
    kv.admit(1, prompt)
    pid = int(kv.block_table[1, 0])
    pool = {"k": jnp.arange(2 * kv.num_pages * 16, dtype=jnp.float32
                            ).reshape(2, kv.num_pages, 16)}
    pool2 = kv.ensure_private(pool, 1, 0)
    new = int(kv.block_table[1, 0])
    assert new != pid                                  # writer remapped
    assert int(kv.block_table[0, 0]) == pid            # sharer untouched
    assert int(kv.refcount[pid]) == 1 and int(kv.refcount[new]) == 1
    np.testing.assert_array_equal(np.asarray(pool2["k"][:, new]),
                                  np.asarray(pool["k"][:, pid]))
    assert kv.stats.cow_copies == 1
    # private page: second call is a no-op
    assert kv.ensure_private(pool2, 1, 0) is pool2


def test_pool_sizing_guard():
    """A pool smaller than max_batch * pages_per_slot could exhaust mid
    admission (a slot always maps exactly pages_per_slot pages); the ctor
    rejects it up front so _acquire's exhaustion error stays unreachable."""
    with pytest.raises(AssertionError):
        PagedKVCache(None, max_batch=2, max_len=64, page_size=16,
                     num_pages=4)


def test_shared_prefix_workload_heads_and_determinism():
    """Every request carries its session's full head verbatim plus a
    bounded suffix; same seeds => same stream (the benchmark's equal-load
    contract between the share-on and share-off rows)."""
    mk = lambda: SharedPrefixWorkload(num_sessions=3, prefix_len=32,
                                      suffix_min=2, suffix_max=5,
                                      vocab_size=97, seed=3)
    wl = mk()
    reqs = list(wl.stream(20, seed=5))
    assert len(reqs) == 20
    for sess, prompt in reqs:
        np.testing.assert_array_equal(prompt[:32], wl.prefixes[sess])
        assert 34 <= len(prompt) <= 37
    for (s1, p1), (s2, p2) in zip(reqs, mk().stream(20, seed=5)):
        assert s1 == s2
        np.testing.assert_array_equal(p1, p2)


def test_scatter_rejects_duplicate_slots():
    cache = {"l0/k": jnp.zeros((1, 4, 8, 2))}
    rows = {"l0/k": jnp.ones((1, 2, 8, 2))}
    out = batch_cache_scatter(cache, rows, jnp.asarray([1, 3], jnp.int32))
    assert float(out["l0/k"][0, 1].sum()) > 0
    with pytest.raises(ValueError, match="duplicate target slots"):
        batch_cache_scatter(cache, rows, jnp.asarray([2, 2], jnp.int32))
