"""Serving engine: continuous batching correctness + CoIC integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.coic import CoICConfig
from repro.models import build_model
from repro.serving.engine import ServingConfig, ServingEngine


@pytest.fixture(scope="module")
def served_model():
    import dataclasses

    # fp32: bf16 near-ties can flip argmax between batched and single-row
    # decode (different reduction order), which is numerics, not scheduling
    cfg = dataclasses.replace(get_config("coic-paper"), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _greedy_reference(model, params, prompt, max_new):
    logits, cache, ln = model.prefill(params, jnp.asarray(prompt[None, :]),
                                      max_len=len(prompt) + max_new + 8)
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out.append(int(tok[0]))
    for _ in range(max_new - 1):
        logits, cache, ln = model.decode_step(params, cache, tok, ln)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0]))
    return np.asarray(out, np.int32)


def test_batched_generation_matches_single(served_model, nprng):
    """Requests served through continuous batching must produce exactly the
    single-request greedy generations."""
    cfg, model, params = served_model
    eng = ServingEngine(model, params, ServingConfig(
        max_batch=4, max_len=96, max_new_tokens=8))
    prompts = [nprng.integers(0, cfg.vocab_size, size=(24,)).astype(np.int32)
               for _ in range(6)]
    rids = [eng.submit(p) for p in prompts]
    eng.run_until_drained()
    assert len(eng.results) == 6
    by_id = {r.req_id: r for r in eng.results}
    for rid, prompt in zip(rids, prompts):
        ref = _greedy_reference(model, params, prompt, 8)
        got = by_id[rid].tokens
        np.testing.assert_array_equal(got, ref)


def test_coic_front_serves_repeats_from_edge(served_model, nprng):
    cfg, model, params = served_model
    eng = ServingEngine(model, params, ServingConfig(
        max_batch=4, max_len=96, max_new_tokens=8,
        coic=CoICConfig(capacity=64, threshold=0.995, descriptor="prefix",
                        k_layers=2)))
    prompt = nprng.integers(0, cfg.vocab_size, size=(24,)).astype(np.int32)
    eng.submit(prompt)
    eng.run_until_drained()
    assert eng.results[0].source == "cloud"
    cloud_tokens = eng.results[0].tokens

    eng.submit(prompt.copy())                      # identical request
    eng.run_until_drained()
    assert eng.results[1].source == "edge"
    np.testing.assert_array_equal(eng.results[1].tokens[:8], cloud_tokens)
    assert eng.results[1].decode_steps == 0        # zero model steps — the win


def test_edge_hit_vs_threshold(served_model, nprng):
    """tau=1.01 (unreachable) => every request goes to the cloud."""
    cfg, model, params = served_model
    eng = ServingEngine(model, params, ServingConfig(
        max_batch=2, max_len=96, max_new_tokens=4,
        coic=CoICConfig(capacity=16, threshold=1.01, descriptor="prefix")))
    prompt = nprng.integers(0, cfg.vocab_size, size=(16,)).astype(np.int32)
    eng.submit(prompt)
    eng.run_until_drained()
    eng.submit(prompt.copy())
    eng.run_until_drained()
    assert [r.source for r in eng.results] == ["cloud", "cloud"]


def test_slots_recycled(served_model, nprng):
    cfg, model, params = served_model
    eng = ServingEngine(model, params, ServingConfig(
        max_batch=2, max_len=64, max_new_tokens=4))
    for _ in range(5):
        eng.submit(nprng.integers(0, cfg.vocab_size, size=(16,)).astype(np.int32))
    eng.run_until_drained()
    assert len(eng.results) == 5
    assert sorted(eng.free_slots) == [0, 1]


def test_overflow_reject_raises_without_consuming_rid(served_model):
    """Prompts longer than max_len must fail loudly at submit() — the old
    behavior silently truncated in _pad_prompts and served tokens
    conditioned on a prompt the caller never sent."""
    from repro.serving.engine import PromptTooLongError

    cfg, model, params = served_model
    eng = ServingEngine(model, params, ServingConfig(
        max_batch=2, max_len=64, max_new_tokens=4))
    with pytest.raises(PromptTooLongError, match="exceeds max_len"):
        eng.submit(np.zeros((65,), np.int32))
    rid = eng.submit(np.zeros((64,), np.int32))    # at capacity: accepted
    assert rid == 0                                # reject consumed no rid
    eng.run_until_drained()
    assert not eng.results[0].truncated


def test_overflow_truncate_serves_head_and_flags(served_model, nprng):
    """on_overflow="truncate" serves the max_len head and stamps the
    result — the same tokens an in-bounds submission of that head gets."""
    cfg, model, params = served_model
    head = nprng.integers(0, cfg.vocab_size, size=(64,)).astype(np.int32)
    long = np.concatenate([head, head])
    eng = ServingEngine(model, params, ServingConfig(
        max_batch=2, max_len=64, max_new_tokens=4, on_overflow="truncate"))
    r_long = eng.submit(long)
    r_head = eng.submit(head)
    eng.run_until_drained()
    by = {r.req_id: r for r in eng.results}
    assert by[r_long].truncated and not by[r_head].truncated
    np.testing.assert_array_equal(by[r_long].tokens, by[r_head].tokens)
