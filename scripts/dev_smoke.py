"""Dev loop: instantiate every reduced arch, run fwd/loss/prefill/decode."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models import build_model

rng = jax.random.PRNGKey(0)

for arch in ARCH_IDS:
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 32
    if cfg.family == "encdec":
        batch = {"enc_embeds": jax.random.normal(rng, (B, S, cfg.d_model), jnp.float32),
                 "dec_tokens": jax.random.randint(rng, (B, 16), 0, cfg.vocab_size)}
        loss, metrics = model.loss(params, batch)
        assert np.isfinite(float(loss)), (arch, float(loss))
        logits, cache, lengths = model.prefill(params, batch["enc_embeds"],
                                               batch["dec_tokens"], max_len=24)
        logits2, cache, lengths = model.decode_step(params, cache,
                                                    jnp.argmax(logits, -1).astype(jnp.int32),
                                                    lengths)
        assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch
    else:
        toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
        batch = {"tokens": toks}
        if cfg.num_image_patches:
            batch["image_embeds"] = jax.random.normal(rng, (B, cfg.num_image_patches, cfg.d_model))
        loss, metrics = model.loss(params, batch)
        assert np.isfinite(float(loss)), (arch, float(loss))
        logits, cache, lengths = model.prefill(params, toks, max_len=S + 8,
                                               image_embeds=batch.get("image_embeds"))
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        logits2, cache, lengths = model.decode_step(params, cache, nxt, lengths)
        assert logits2.shape == (B, cfg.vocab_size), (arch, logits2.shape)
        assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch
    print(f"OK {arch:28s} loss={float(loss):.4f}")
print("all smoke OK")
