#!/usr/bin/env python
"""Validate a Chrome trace-event JSON exported by ``repro.obs.trace``.

Structural contract (what Perfetto / chrome://tracing needs to render it,
plus this repo's span taxonomy — see docs/observability.md):

  * top level is ``{"traceEvents": [...]}``;
  * every duration-begin ``B`` has a matching ``E`` on the same
    (pid, tid) track, properly nested (checked with a per-track stack),
    and nothing is left open at the end;
  * complete ``X`` events carry numeric ``ts`` and ``dur >= 0``;
  * engine-track request markers (``cat == "request"``) occur INSIDE an
    open ``step`` span — retirement always happens within an engine step;
  * on the modeled-requests track each outer ``request`` span's duration
    equals the sum of its ``request_term`` children, and the children
    tile it end-to-end (each term starts where the previous ended) —
    i.e. the trace reconstructs ``ServedResult.completion_ms`` per tier.

``--metrics registry.json`` additionally re-pins the dispatch bounds from
the metrics snapshot: ``engine/max_step_ladder <= 2`` and
``ladder/max_ladder_dispatches <= 4``.

Importable: ``validate(trace_dict)`` / ``check_metrics(snapshot_dict)``
raise ``TraceError`` on the first violation (tests/test_obs.py reuses
them); the CLI exits non-zero with the message.
"""
from __future__ import annotations

import argparse
import json
import sys

# one µs of slack: term durations are float ms * 1e3 sums
TOL_US = 1.0


class TraceError(AssertionError):
    pass


def _check(cond, msg):
    if not cond:
        raise TraceError(msg)


def validate(trace: dict) -> dict:
    """Raise TraceError on the first structural violation.  Returns
    summary stats (span counts per name, request count) for reporting."""
    _check(isinstance(trace, dict) and isinstance(
        trace.get("traceEvents"), list), "top level must be {traceEvents: []}")
    events = trace["traceEvents"]
    _check(len(events) > 0, "empty trace")

    stacks: dict[tuple, list] = {}          # (pid, tid) -> open B names
    spans: dict[str, int] = {}
    requests = 0
    for i, e in enumerate(events):
        ph = e.get("ph")
        _check(ph in ("B", "E", "X", "M", "i"), f"event {i}: bad ph {ph!r}")
        key = (e.get("pid"), e.get("tid"))
        if ph == "B":
            _check(isinstance(e.get("ts"), (int, float)),
                   f"event {i}: B without numeric ts")
            if e.get("cat") == "request":
                # retire-time marker: must sit inside an open step span
                _check("step" in stacks.get(key, []),
                       f"event {i}: request marker outside a step span")
            stacks.setdefault(key, []).append(e["name"])
            spans[e["name"]] = spans.get(e["name"], 0) + 1
        elif ph == "E":
            _check(stacks.get(key),
                   f"event {i}: E with no open B on track {key}")
            stacks[key].pop()
        elif ph == "X":
            _check(isinstance(e.get("ts"), (int, float))
                   and isinstance(e.get("dur"), (int, float))
                   and e["dur"] >= 0, f"event {i}: X needs ts and dur >= 0")
    for key, open_names in stacks.items():
        _check(not open_names,
               f"unclosed spans {open_names} on track {key}")

    # modeled-request reconstruction: outer dur == sum(child durs), tiled
    outers = {}       # (pid, tid) -> outer X event
    terms: dict[tuple, list] = {}
    for e in events:
        if e.get("ph") != "X":
            continue
        key = (e.get("pid"), e.get("tid"))
        if e.get("cat") == "request_model":
            _check(key not in outers,
                   f"two request spans on one request track {key}")
            outers[key] = e
        elif e.get("cat") == "request_term":
            terms.setdefault(key, []).append(e)
    for key, outer in outers.items():
        requests += 1
        kids = sorted(terms.get(key, []), key=lambda e: e["ts"])
        _check(kids, f"request on track {key} has no term children")
        total = sum(k["dur"] for k in kids)
        _check(abs(total - outer["dur"]) <= TOL_US,
               f"track {key}: term sum {total} != request dur "
               f"{outer['dur']}")
        cursor = outer["ts"]
        for k in kids:
            _check(abs(k["ts"] - cursor) <= TOL_US,
                   f"track {key}: term {k['name']!r} at {k['ts']} leaves a "
                   f"gap (expected {cursor})")
            cursor = k["ts"] + k["dur"]
    for key in terms:
        _check(key in outers, f"orphan request_term events on track {key}")
    _check(requests > 0, "no modeled request spans in trace")
    return {"events": len(events), "requests": requests, "spans": spans}


def check_metrics(snapshot: dict, *, max_step_ladder: int = 2,
                  max_fed_ladder: int = 4) -> None:
    """Re-pin the per-step dispatch bounds from a registry snapshot."""
    step = snapshot.get("engine/max_step_ladder")
    _check(step is not None, "snapshot missing engine/max_step_ladder")
    _check(step <= max_step_ladder,
           f"engine/max_step_ladder {step} > {max_step_ladder}")
    fed = snapshot.get("ladder/max_ladder_dispatches")
    _check(fed is not None, "snapshot missing ladder/max_ladder_dispatches")
    _check(fed <= max_fed_ladder,
           f"ladder/max_ladder_dispatches {fed} > {max_fed_ladder}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome trace-event JSON to validate")
    ap.add_argument("--metrics", default="",
                    help="metrics registry snapshot JSON: also assert the "
                         "ladder dispatch bounds")
    args = ap.parse_args(argv)
    try:
        with open(args.trace) as f:
            stats = validate(json.load(f))
        if args.metrics:
            with open(args.metrics) as f:
                check_metrics(json.load(f))
    except TraceError as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 1
    top = sorted(stats["spans"].items(), key=lambda kv: -kv[1])[:8]
    print(f"OK: {stats['events']} events, {stats['requests']} request "
          f"timelines, top spans: "
          + ", ".join(f"{n}={c}" for n, c in top))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
