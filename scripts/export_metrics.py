#!/usr/bin/env python
"""Export a metrics registry snapshot as Prometheus text exposition.

Input is the flat JSON written by ``MetricsRegistry.export`` (e.g. the
``--metrics-out metrics.json`` of ``benchmarks/run.py``); output is the
Prometheus text format, suitable for a node_exporter textfile collector
or a pushgateway.  Scalars render as gauges; histogram snapshots render
as summaries (``quantile`` labels + ``_sum``/``_count``) — the snapshot
has already collapsed the log-spaced buckets into percentiles.  For
full-fidelity ``le``-bucket histograms, call
``repro.obs.metrics.export_prometheus`` on the LIVE registry instead
(same sanitization, same deterministic rendering).

Usage:
    PYTHONPATH=src python scripts/export_metrics.py metrics.json
    PYTHONPATH=src python scripts/export_metrics.py metrics.json -o out.prom
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("snapshot", help="MetricsRegistry snapshot JSON")
    ap.add_argument("-o", "--out", default="",
                    help="write Prometheus text here (default: stdout)")
    args = ap.parse_args(argv)

    from repro.obs.metrics import snapshot_to_prometheus

    with open(args.snapshot) as f:
        snap = json.load(f)
    text = snapshot_to_prometheus(snap, path=args.out or None)
    if not args.out:
        sys.stdout.write(text)
    else:
        print(f"wrote {args.out}: {len(text.splitlines())} lines, "
              f"{len(snap)} metrics")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
